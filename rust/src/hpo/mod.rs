//! Hyper-parameter tuning: search spaces, Random Search, TPE, the
//! Hyperband scheduler, and the tuner loop that evaluates configurations
//! with subset-based training (the AUTOMATA protocol the paper adopts,
//! with MILO replacing the subset selector).

pub mod hyperband;
pub mod space;
pub mod tpe;

use std::sync::Arc;

use anyhow::Result;

pub use hyperband::{hyperband_brackets, Bracket};
pub use space::{HpoSpace, TrialConfig};
pub use tpe::TpeSampler;

use crate::coordinator::{Metadata, PreprocessOptions, StrategyKind};
use crate::data::Dataset;
use crate::runtime::Runtime;
use crate::session::MetaSource;
use crate::train::{LrSchedule, TrainConfig, Trainer};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Search algorithm choice (paper Fig. 7: Random+HB and TPE+HB).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchAlgo {
    Random,
    Tpe,
}

impl SearchAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            SearchAlgo::Random => "random_search",
            SearchAlgo::Tpe => "tpe",
        }
    }
}

/// Tuning-run configuration.
#[derive(Clone, Debug)]
pub struct HpoConfig {
    pub algo: SearchAlgo,
    /// Subset strategy used inside every configuration evaluation.
    pub strategy: StrategyKind,
    pub fraction: f64,
    /// Hyperband maximum resource (epochs per configuration at full rung).
    pub max_epochs: usize,
    /// Hyperband reduction factor η.
    pub eta: usize,
    /// Number of configurations sampled per bracket start.
    pub seed: u64,
}

impl Default for HpoConfig {
    fn default() -> Self {
        HpoConfig {
            algo: SearchAlgo::Random,
            strategy: StrategyKind::Milo { kappa: crate::selection::milo::DEFAULT_KAPPA },
            fraction: 0.1,
            max_epochs: 27,
            eta: 3,
            seed: 1,
        }
    }
}

/// One evaluated trial.
#[derive(Clone, Debug)]
pub struct TrialResult {
    pub config: TrialConfig,
    pub epochs: usize,
    pub val_accuracy: f64,
    pub train_secs: f64,
}

/// Outcome of a tuning run.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    pub best: TrialResult,
    /// Test accuracy of the best configuration retrained at full rung on
    /// the same subset strategy.
    pub best_test_accuracy: f64,
    pub tuning_secs: f64,
    pub trials: Vec<TrialResult>,
}

/// The tuner: Hyperband over configurations supplied by the search
/// algorithm, each evaluated by subset training.
pub struct Tuner<'a> {
    pub rt: &'a Runtime,
    pub ds: &'a Dataset,
    pub cfg: HpoConfig,
    pub space: HpoSpace,
    /// Pre-processing metadata, shared by every configuration evaluation —
    /// the amortization that makes MILO tuning fast.
    pub metadata: Option<Arc<Metadata>>,
    /// Where metadata comes from when it is not preset: inline pass,
    /// content-addressed store, or a running `milo serve` instance (N
    /// concurrent tuners then share exactly one pass server-side). `None`
    /// defaults to an inline native-backend pass at the tuner's
    /// fraction/seed.
    pub source: Option<MetaSource>,
    pub verbose: bool,
}

impl<'a> Tuner<'a> {
    pub fn new(rt: &'a Runtime, ds: &'a Dataset, cfg: HpoConfig) -> Tuner<'a> {
        Tuner {
            rt,
            ds,
            space: HpoSpace::default_for(ds),
            metadata: None,
            source: None,
            verbose: false,
            cfg,
        }
    }

    /// Evaluate one configuration for `epochs`; returns val accuracy.
    pub fn evaluate(
        &self,
        config: &TrialConfig,
        epochs: usize,
        sw: &mut Stopwatch,
    ) -> Result<TrialResult> {
        let schedule = match config.scheduler {
            space::SchedulerChoice::Cosine => LrSchedule::Cosine { total: epochs },
            space::SchedulerChoice::StepDecay => LrSchedule::StepDecay {
                gamma: config.gamma,
                every: (epochs / 3).max(1),
            },
        };
        let tc = TrainConfig {
            epochs,
            fraction: if matches!(self.cfg.strategy, StrategyKind::Full) {
                1.0
            } else {
                self.cfg.fraction
            },
            r: 1,
            hidden: config.hidden,
            seed: 1, // same init for every trial (paper: same seed across methods)
            lr: config.lr,
            momentum: config.momentum,
            weight_decay: 5e-4,
            nesterov: config.nesterov,
            schedule,
            eval_every: 0,
            time_budget_secs: None,
        };
        let mut strategy = self
            .cfg
            .strategy
            .build(self.metadata.as_deref(), None)?;
        let mut trainer = Trainer::new(self.rt, self.ds, tc)?;
        let out = sw.time("trials", || trainer.run(strategy.as_mut()))?;
        let val = trainer
            .into_model()
            .evaluate(self.rt, self.ds, crate::data::Split::Val)?;
        Ok(TrialResult {
            config: config.clone(),
            epochs,
            val_accuracy: val.accuracy,
            train_secs: out.train_secs,
        })
    }

    /// Run the tuning loop: Hyperband brackets over configs from the
    /// search algorithm.
    pub fn run(&mut self) -> Result<TuneOutcome> {
        let mut sw = Stopwatch::new();
        let mut rng = Rng::new(self.cfg.seed ^ 0x49_50_4F).derive_str(self.cfg.strategy.name());

        // Pre-processing (once; shared by all trials), through the tuner's
        // MetaSource: a served or store-backed source means the pass
        // already happened elsewhere and this tuner (and any others
        // pointed at the same source) pays nothing.
        if self.cfg.strategy.needs_metadata() && self.metadata.is_none() {
            // Re-target the source at this tuner's fraction/seed (on a
            // remote source this sets the expectations), so a source
            // configured for a different cell can never silently hand
            // over mismatched selections.
            let source = self
                .source
                .clone()
                .unwrap_or_else(|| {
                    MetaSource::inline(PreprocessOptions {
                        backend: crate::kernel::SimilarityBackend::Native,
                        ..Default::default()
                    })
                })
                .with_fraction(self.cfg.fraction)
                .with_seed(self.cfg.seed);
            let meta =
                sw.time("preprocess", || source.resolve(Some(self.rt), self.ds))?;
            self.metadata = Some(meta);
        }

        let mut tpe = TpeSampler::new(self.space.clone(), 0.25);
        let mut all: Vec<TrialResult> = Vec::new();
        for bracket in hyperband_brackets(self.cfg.max_epochs, self.cfg.eta) {
            // sample bracket.n_configs configurations
            let mut configs: Vec<TrialConfig> = (0..bracket.n_configs)
                .map(|_| match self.cfg.algo {
                    SearchAlgo::Random => self.space.sample(&mut rng),
                    SearchAlgo::Tpe => tpe.sample(&all, &mut rng),
                })
                .collect();
            // successive halving
            for rung in &bracket.rungs {
                let mut results: Vec<TrialResult> = Vec::with_capacity(configs.len());
                for cfg in &configs {
                    let r = self.evaluate(cfg, rung.epochs, &mut sw)?;
                    if self.verbose {
                        eprintln!(
                            "[tuner] {} e={} val={:.4} {:?}",
                            self.cfg.strategy.name(),
                            rung.epochs,
                            r.val_accuracy,
                            cfg
                        );
                    }
                    results.push(r);
                }
                results.sort_by(|a, b| b.val_accuracy.partial_cmp(&a.val_accuracy).unwrap());
                all.extend(results.iter().cloned());
                configs = results
                    .iter()
                    .take(rung.keep)
                    .map(|r| r.config.clone())
                    .collect();
                if configs.is_empty() {
                    break;
                }
            }
        }

        let best = all
            .iter()
            .max_by(|a, b| {
                (a.val_accuracy, a.epochs)
                    .partial_cmp(&(b.val_accuracy, b.epochs))
                    .unwrap()
            })
            .expect("no trials ran")
            .clone();

        // final: retrain best config at max rung, report test accuracy
        let final_trial = self.evaluate(&best.config, self.cfg.max_epochs, &mut sw)?;
        let tc_best = final_trial.config.clone();
        let schedule = match tc_best.scheduler {
            space::SchedulerChoice::Cosine => LrSchedule::Cosine { total: self.cfg.max_epochs },
            space::SchedulerChoice::StepDecay => LrSchedule::StepDecay {
                gamma: tc_best.gamma,
                every: (self.cfg.max_epochs / 3).max(1),
            },
        };
        let mut strategy = self.cfg.strategy.build(self.metadata.as_deref(), None)?;
        let tc = TrainConfig {
            epochs: self.cfg.max_epochs,
            fraction: if matches!(self.cfg.strategy, StrategyKind::Full) {
                1.0
            } else {
                self.cfg.fraction
            },
            hidden: tc_best.hidden,
            lr: tc_best.lr,
            momentum: tc_best.momentum,
            nesterov: tc_best.nesterov,
            schedule,
            eval_every: 0,
            ..Default::default()
        };
        let mut trainer = Trainer::new(self.rt, self.ds, tc)?;
        sw.time("trials", || trainer.run(strategy.as_mut()))?;
        let test = trainer
            .into_model()
            .evaluate(self.rt, self.ds, crate::data::Split::Test)?;

        Ok(TuneOutcome {
            best,
            best_test_accuracy: test.accuracy,
            tuning_secs: sw.secs("preprocess") + sw.secs("trials"),
            trials: all,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;

    fn runtime() -> Option<Runtime> {
        crate::testkit::artifacts_or_skip()
    }

    #[test]
    fn tiny_tuning_run_completes() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::RottenLike.generate(1);
        let cfg = HpoConfig {
            algo: SearchAlgo::Random,
            strategy: StrategyKind::AdaptiveRandom,
            fraction: 0.1,
            max_epochs: 4,
            eta: 2,
            seed: 1,
        };
        let mut tuner = Tuner::new(&rt, &ds, cfg);
        let out = tuner.run().unwrap();
        assert!(!out.trials.is_empty());
        assert!(out.best.val_accuracy >= 0.3);
        assert!(out.best_test_accuracy > 0.3);
        assert!(out.tuning_secs > 0.0);
    }

    #[test]
    fn tuner_runs_against_served_metadata() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::RottenLike.generate(3);
        // one preprocessing pass, served; the tuner fetches instead of
        // recomputing
        let pre = crate::coordinator::Preprocessor::with_options(
            &rt,
            crate::coordinator::PreprocessOptions {
                fraction: 0.1,
                backend: crate::kernel::SimilarityBackend::Native,
                seed: 3,
                ..Default::default()
            },
        );
        let meta = std::sync::Arc::new(pre.run(&ds).unwrap());
        let server =
            crate::serve::SubsetServer::bind("127.0.0.1:0", meta.clone(), None, 3)
                .unwrap();
        let cfg = HpoConfig {
            algo: SearchAlgo::Random,
            strategy: StrategyKind::Milo { kappa: 1.0 / 6.0 },
            fraction: 0.1,
            max_epochs: 4,
            eta: 2,
            seed: 3,
        };
        let (seed, fraction) = (cfg.seed, cfg.fraction);
        let mut tuner = Tuner::new(&rt, &ds, cfg);
        tuner.source = Some(MetaSource::remote_expecting(
            server.addr().to_string(),
            seed,
            fraction,
        ));
        let out = tuner.run().unwrap();
        assert!(!out.trials.is_empty());
        // the tuner's metadata is the served pass, not a local recompute
        assert_eq!(
            tuner.metadata.as_ref().unwrap().sge_subsets,
            meta.sge_subsets
        );
        server.shutdown();
    }

    #[test]
    fn milo_tuning_amortizes_preprocessing() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::RottenLike.generate(2);
        let cfg = HpoConfig {
            algo: SearchAlgo::Tpe,
            strategy: StrategyKind::Milo { kappa: 1.0 / 6.0 },
            fraction: 0.1,
            max_epochs: 4,
            eta: 2,
            seed: 2,
        };
        let mut tuner = Tuner::new(&rt, &ds, cfg);
        let out = tuner.run().unwrap();
        // metadata computed exactly once despite many trials
        assert!(tuner.metadata.is_some());
        assert!(out.trials.len() >= 2);
    }
}
