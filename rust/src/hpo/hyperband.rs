//! The Hyperband scheduler (Li et al., JMLR 2017): brackets of successive
//! halving with different exploration/exploitation trade-offs.

/// One rung of successive halving: run every surviving config for
/// `epochs`, keep the best `keep`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rung {
    pub epochs: usize,
    pub keep: usize,
}

/// One Hyperband bracket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bracket {
    pub s: usize,
    pub n_configs: usize,
    pub rungs: Vec<Rung>,
}

/// Standard Hyperband bracket construction for max resource `r_max`
/// (epochs) and reduction factor `eta`.
pub fn hyperband_brackets(r_max: usize, eta: usize) -> Vec<Bracket> {
    assert!(eta >= 2, "eta must be >= 2");
    assert!(r_max >= 1);
    let s_max = (r_max as f64).log(eta as f64).floor() as usize;
    let b = (s_max + 1) as f64;
    let mut out = Vec::new();
    for s in (0..=s_max).rev() {
        let n = ((b / (s as f64 + 1.0)) * (eta as f64).powi(s as i32)).ceil() as usize;
        let r0 = r_max as f64 * (eta as f64).powi(-(s as i32));
        let mut rungs = Vec::new();
        let mut n_i = n;
        for i in 0..=s {
            let epochs = (r0 * (eta as f64).powi(i as i32)).round().max(1.0) as usize;
            let keep = (n_i / eta).max(if i == s { 1 } else { 1 });
            rungs.push(Rung { epochs: epochs.min(r_max), keep });
            n_i = keep;
        }
        // final rung keeps 1 (the bracket winner)
        if let Some(last) = rungs.last_mut() {
            last.keep = 1;
        }
        out.push(Bracket { s, n_configs: n, rungs });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_r27_eta3() {
        let brackets = hyperband_brackets(27, 3);
        // s_max = 3: four brackets
        assert_eq!(brackets.len(), 4);
        // the most exploratory bracket: 27 configs at 1 epoch first rung
        assert_eq!(brackets[0].s, 3);
        assert_eq!(brackets[0].n_configs, 27);
        assert_eq!(brackets[0].rungs[0].epochs, 1);
        assert_eq!(brackets[0].rungs.last().unwrap().epochs, 27);
        // the most exploitative bracket: few configs straight at 27 epochs
        let last = brackets.last().unwrap();
        assert_eq!(last.s, 0);
        assert_eq!(last.rungs.len(), 1);
        assert_eq!(last.rungs[0].epochs, 27);
    }

    #[test]
    fn rung_epochs_increase_and_keep_decreases() {
        for b in hyperband_brackets(81, 3) {
            for w in b.rungs.windows(2) {
                assert!(w[1].epochs > w[0].epochs);
                assert!(w[1].keep <= w[0].keep.max(1));
            }
            assert_eq!(b.rungs.last().unwrap().keep, 1);
        }
    }

    #[test]
    fn small_budgets_still_valid() {
        let b = hyperband_brackets(4, 2);
        assert!(!b.is_empty());
        for br in &b {
            assert!(br.n_configs >= 1);
            assert!(!br.rungs.is_empty());
            for r in &br.rungs {
                assert!(r.epochs >= 1 && r.epochs <= 4);
            }
        }
    }
}
