//! Dependency-free building blocks: RNG, JSON, math helpers, timing,
//! a tiny thread-pool `par_map`, and CLI argument parsing.

pub mod args;
pub mod json;
pub mod math;
pub mod rng;
pub mod threads;
pub mod timer;
