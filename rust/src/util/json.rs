//! Minimal JSON parser + writer.
//!
//! The build environment vendors only the `xla` crate's dependency closure,
//! so serde is unavailable; this module provides the small JSON surface the
//! coordinator needs: parsing `artifacts/manifest.json` and metadata files,
//! and writing experiment result records. It is a strict recursive-descent
//! parser over UTF-8 with the usual escape handling — not a general
//! replacement for serde, but fully covered by tests below.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other:?}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected bool, got {other:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(anyhow!("expected array, got {other:?}")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(anyhow!("expected object, got {other:?}")),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    // -- construction helpers --------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.pos += 4;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| anyhow!("bad surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2)?,
                                        16,
                                    )?;
                                    self.pos += 6;
                                    0x10000
                                        + ((code - 0xD800) << 10)
                                        + (lo - 0xDC00)
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                code
                            };
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                _ => {
                    // multi-byte UTF-8: copy raw bytes through
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    out.push_str(std::str::from_utf8(
                        &self.bytes[start..self.pos],
                    )?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected , or ] at {}, got {:?}", self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected , or }} at {}, got {:?}", self.pos, c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(!arr[2].get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true],"s":"a\"b\\c","z":{"k":-7}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn parses_real_manifest_like_doc() {
        let doc = r#"{
          "version": 1, "batch": 128,
          "artifacts": [
            {"name": "encoder_cifar10", "file": "encoder_cifar10.hlo.txt",
             "inputs": [{"shape": [128, 64], "dtype": "float32"}]}
          ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("batch").unwrap().as_usize().unwrap(), 128);
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        let shape = a.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(shape, vec![128, 64]);
    }

    #[test]
    fn usize_rejects_fractional() {
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
    }
}
