//! Deterministic pseudo-random number generation.
//!
//! Every stochastic component in the pipeline (dataset synthesis, stochastic
//! greedy, WRE sampling, baselines, HPO search) draws from an [`Rng`] seeded
//! through [`Rng::derive`] streams off a single experiment seed, so a run is
//! exactly reproducible from its seed (DESIGN.md §7).
//!
//! The generator is SplitMix64 for stream derivation feeding a
//! xoshiro256++ core — fast, high-quality, and trivially portable (no
//! external crates available offline).

/// Splits a seed into a well-distributed 64-bit state word.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG with SplitMix64 seeding and hierarchical stream
/// derivation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the last Box-Muller draw.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed a generator. Identical seeds yield identical streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream labelled by `tag` — used to give
    /// each pipeline component (data gen, SGE, WRE, trainer shuffling, …)
    /// its own stream so adding draws to one component never perturbs
    /// another.
    pub fn derive(&self, tag: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ tag.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive a child stream from a string label (stable hash).
    pub fn derive_str(&self, tag: &str) -> Rng {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in tag.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        self.derive(h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Log-uniform in `[lo, hi)` — the standard scale for learning-rate
    /// search spaces.
    #[inline]
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        (self.range_f64(lo.ln(), hi.ln())).exp()
    }

    /// Standard normal deviate (Box-Muller, with the spare cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std as f32 (the data generators' workhorse).
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` — Floyd's algorithm when `k` is
    /// small relative to `n`, shuffle otherwise. Output is sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        if k * 3 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        // Floyd's: O(k) expected.
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out.sort_unstable();
        out
    }

    /// Sample one index from an unnormalized non-negative weight vector.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: zero total weight");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn derive_streams_are_independent() {
        let root = Rng::new(7);
        let mut a = root.derive(1);
        let mut b = root.derive(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
        // re-derivation reproduces the stream
        let mut a2 = root.derive(1);
        assert_eq!(xs[0], a2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_roughly() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(4);
        for &(n, k) in &[(100, 5), (100, 50), (100, 100), (10, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(6);
        let w = [0.0, 0.0, 1.0, 3.0];
        let mut counts = [0usize; 4];
        for _ in 0..20_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[1], 0);
        let ratio = counts[3] as f64 / counts[2] as f64;
        assert!((2.5..3.5).contains(&ratio), "{counts:?}");
    }

    #[test]
    fn log_uniform_within_bounds() {
        let mut r = Rng::new(8);
        for _ in 0..1000 {
            let x = r.log_uniform(1e-4, 1e-1);
            assert!((1e-4..1e-1).contains(&x));
        }
    }
}
