//! Small numeric helpers shared across the pipeline.

/// Taylor-Softmax (paper Eq. 5): `p_i ∝ 1 + g_i + 0.5 g_i²`.
///
/// Unlike exponential softmax this is numerically benign for any finite
/// input and, per de Brébisson & Vincent (2016), yields a heavier-tailed,
/// better-exploring distribution over importance scores — exactly why the
/// paper uses it for WRE.
pub fn taylor_softmax(g: &[f64]) -> Vec<f64> {
    let terms: Vec<f64> = g.iter().map(|&x| 1.0 + x + 0.5 * x * x).collect();
    let total: f64 = terms.iter().sum();
    assert!(total > 0.0, "taylor_softmax: degenerate total {total}");
    terms.into_iter().map(|t| t / total).collect()
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f32]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64;
    var.sqrt()
}

/// Median (copies + sorts; fine for metric-sized slices).
pub fn median(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2] as f64
    } else {
        0.5 * (v[n / 2 - 1] as f64 + v[n / 2] as f64)
    }
}

/// argmax over f32 slice; ties resolve to the lowest index.
pub fn argmax(xs: &[f32]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Kendall rank correlation coefficient (tau-a) between two score vectors
/// interpreted as rankings of the same items. Used for the Table 9
/// hyper-parameter ordering-retention analysis.
pub fn kendall_tau(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let mut concordant = 0i64;
    let mut discordant = 0i64;
    for i in 0..n {
        for j in (i + 1)..n {
            let da = a[i] - a[j];
            let db = b[i] - b[j];
            let s = da * db;
            if s > 0.0 {
                concordant += 1;
            } else if s < 0.0 {
                discordant += 1;
            }
            // ties contribute zero (tau-a)
        }
    }
    let pairs = (n * (n - 1) / 2) as f64;
    (concordant - discordant) as f64 / pairs
}

/// Round-up integer division.
pub const fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Round `n` up to the next multiple of `m`.
pub const fn round_up(n: usize, m: usize) -> usize {
    div_ceil(n, m) * m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn taylor_softmax_is_distribution() {
        let p = taylor_softmax(&[0.0, 1.0, 2.0, -0.5]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p.iter().all(|&x| x > 0.0));
        // monotone in g for g >= 0
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn taylor_softmax_matches_formula() {
        let g = [1.0, 3.0];
        let p = taylor_softmax(&g);
        let t1 = 1.0 + 1.0 + 0.5;
        let t2 = 1.0 + 3.0 + 4.5;
        assert!((p[0] - t1 / (t1 + t2)).abs() < 1e-12);
    }

    #[test]
    fn stats_basics() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-9);
        assert!((median(&xs) - 2.5).abs() < 1e-9);
        assert!((median(&[1.0f32, 2.0, 9.0]) - 2.0).abs() < 1e-9);
        assert!((stddev(&xs) - 1.118033988).abs() < 1e-6);
    }

    #[test]
    fn kendall_perfect_and_reversed() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0, 40.0];
        let c = [4.0, 3.0, 2.0, 1.0];
        assert!((kendall_tau(&a, &b) - 1.0).abs() < 1e-12);
        assert!((kendall_tau(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_partial() {
        // one swapped adjacent pair out of 6 pairs: tau = (5-1)/6
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 1.0, 3.0, 4.0];
        assert!((kendall_tau(&a, &b) - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn rounding_helpers() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(round_up(10, 4), 12);
        assert_eq!(round_up(8, 4), 8);
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
    }
}
