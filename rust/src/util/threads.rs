//! Scoped-thread fan-out (`par_map`) — the offline stand-in for rayon.
//!
//! Used by the kernel builder (per-class similarity blocks) and the
//! experiment runner (independent trials). Work is chunked over at most
//! `available_parallelism()` OS threads via `std::thread::scope`, so no
//! runtime or unsafe code is needed.

/// Map `f` over `items` in parallel, preserving order.
pub fn par_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let workers = max_threads().min(items.len().max(1));
    if workers <= 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    // Hand out (index, item) pairs through a mutex-guarded iterator so load
    // imbalance (class sizes vary a lot) self-levels.
    let queue = std::sync::Mutex::new(items.into_iter().enumerate());
    let out = std::sync::Mutex::new(&mut slots);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let next = { queue.lock().unwrap().next() };
                match next {
                    Some((i, item)) => {
                        let r = f(item);
                        out.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker died")).collect()
}

/// Number of worker threads to use (respects `MILO_THREADS`).
pub fn max_threads() -> usize {
    if let Ok(v) = std::env::var("MILO_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let xs: Vec<usize> = (0..257).collect();
        let ys = par_map(xs.clone(), |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(par_map(Vec::<usize>::new(), |x| x), Vec::<usize>::new());
        assert_eq!(par_map(vec![7], |x| x + 1), vec![8]);
    }

    #[test]
    fn uneven_work_self_levels() {
        // items with wildly different costs still come back ordered
        let xs: Vec<usize> = (0..64).collect();
        let ys = par_map(xs, |x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i as u64);
            }
            (x, acc)
        });
        for (i, (x, _)) in ys.iter().enumerate() {
            assert_eq!(i, *x);
        }
    }
}
