//! Wall-clock timing + a simple scoped-section profiler.
//!
//! Every experiment reports both model-quality metrics and elapsed time
//! (the paper's headline axis is *speedup*), so timing is first-class: the
//! [`Stopwatch`] accumulates named sections and the trainer tags
//! selection-time vs step-time vs eval-time separately, which is how we
//! reproduce Figure 1's "fast per-epoch but slow per-wallclock" effect for
//! the gradient-based baselines.
//!
//! Section names are `Cow<'static, str>`, so both static labels
//! (`sw.time("selection", ..)`) and dynamically built ones
//! (`sw.time(format!("class_{c}"), ..)`) work without leaking. Timed
//! sections also run inside an [`obs::Span`](crate::obs::Span), so every
//! Stopwatch section shows up in the global telemetry registry (as
//! `span.<name>`) and the `MILO_TRACE` event log alongside the rest of
//! the system's spans.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulates wall-clock time per named section.
#[derive(Default, Debug, Clone)]
pub struct Stopwatch {
    totals: BTreeMap<Cow<'static, str>, Duration>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `name` (also recorded as an obs span).
    pub fn time<R>(
        &mut self,
        name: impl Into<Cow<'static, str>>,
        f: impl FnOnce() -> R,
    ) -> R {
        let name = name.into();
        let span = crate::obs::Span::enter(name.clone());
        let t0 = Instant::now();
        let r = f();
        let elapsed = t0.elapsed();
        drop(span);
        self.add(name, elapsed);
        r
    }

    pub fn add(&mut self, name: impl Into<Cow<'static, str>>, d: Duration) {
        *self.totals.entry(name.into()).or_default() += d;
    }

    pub fn get(&self, name: &str) -> Duration {
        self.totals.get(name).copied().unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.totals.values().sum()
    }

    pub fn secs(&self, name: &str) -> f64 {
        self.get(name).as_secs_f64()
    }

    pub fn sections(&self) -> impl Iterator<Item = (&str, Duration)> + '_ {
        self.totals.iter().map(|(k, v)| (k.as_ref(), *v))
    }

    pub fn merge(&mut self, other: &Stopwatch) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_default() += *v;
        }
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, d) in &self.totals {
            out.push_str(&format!("{name:>16}: {:.3}s\n", d.as_secs_f64()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_sections() {
        let mut sw = Stopwatch::new();
        sw.add("a", Duration::from_millis(10));
        sw.add("a", Duration::from_millis(5));
        sw.add("b", Duration::from_millis(1));
        assert_eq!(sw.get("a"), Duration::from_millis(15));
        assert_eq!(sw.total(), Duration::from_millis(16));
        assert_eq!(sw.get("missing"), Duration::ZERO);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut sw = Stopwatch::new();
        let v = sw.time("x", || 42);
        assert_eq!(v, 42);
        assert!(sw.get("x") > Duration::ZERO || sw.get("x") == Duration::ZERO);
    }

    #[test]
    fn dynamic_section_names() {
        let mut sw = Stopwatch::new();
        for c in 0..3u64 {
            sw.add(format!("class_{c}"), Duration::from_millis(c + 1));
        }
        assert_eq!(sw.get("class_1"), Duration::from_millis(2));
        let names: Vec<String> =
            sw.sections().map(|(name, _)| name.to_string()).collect();
        assert_eq!(names, vec!["class_0", "class_1", "class_2"]);
    }

    #[test]
    fn merge_sums() {
        let mut a = Stopwatch::new();
        a.add("s", Duration::from_millis(3));
        let mut b = Stopwatch::new();
        b.add("s", Duration::from_millis(4));
        b.add("t", Duration::from_millis(1));
        a.merge(&b);
        assert_eq!(a.get("s"), Duration::from_millis(7));
        assert_eq!(a.get("t"), Duration::from_millis(1));
    }
}
