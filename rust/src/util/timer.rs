//! Wall-clock timing + a simple scoped-section profiler.
//!
//! Every experiment reports both model-quality metrics and elapsed time
//! (the paper's headline axis is *speedup*), so timing is first-class: the
//! [`Stopwatch`] accumulates named sections and the trainer tags
//! selection-time vs step-time vs eval-time separately, which is how we
//! reproduce Figure 1's "fast per-epoch but slow per-wallclock" effect for
//! the gradient-based baselines.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulates wall-clock time per named section.
#[derive(Default, Debug, Clone)]
pub struct Stopwatch {
    totals: BTreeMap<&'static str, Duration>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `name`.
    pub fn time<R>(&mut self, name: &'static str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add(name, t0.elapsed());
        r
    }

    pub fn add(&mut self, name: &'static str, d: Duration) {
        *self.totals.entry(name).or_default() += d;
    }

    pub fn get(&self, name: &str) -> Duration {
        self.totals.get(name).copied().unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.totals.values().sum()
    }

    pub fn secs(&self, name: &str) -> f64 {
        self.get(name).as_secs_f64()
    }

    pub fn sections(&self) -> impl Iterator<Item = (&'static str, Duration)> + '_ {
        self.totals.iter().map(|(k, v)| (*k, *v))
    }

    pub fn merge(&mut self, other: &Stopwatch) {
        for (k, v) in &other.totals {
            *self.totals.entry(k).or_default() += *v;
        }
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, d) in &self.totals {
            out.push_str(&format!("{name:>16}: {:.3}s\n", d.as_secs_f64()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_sections() {
        let mut sw = Stopwatch::new();
        sw.add("a", Duration::from_millis(10));
        sw.add("a", Duration::from_millis(5));
        sw.add("b", Duration::from_millis(1));
        assert_eq!(sw.get("a"), Duration::from_millis(15));
        assert_eq!(sw.total(), Duration::from_millis(16));
        assert_eq!(sw.get("missing"), Duration::ZERO);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut sw = Stopwatch::new();
        let v = sw.time("x", || 42);
        assert_eq!(v, 42);
        assert!(sw.get("x") > Duration::ZERO || sw.get("x") == Duration::ZERO);
    }

    #[test]
    fn merge_sums() {
        let mut a = Stopwatch::new();
        a.add("s", Duration::from_millis(3));
        let mut b = Stopwatch::new();
        b.add("s", Duration::from_millis(4));
        b.add("t", Duration::from_millis(1));
        a.merge(&b);
        assert_eq!(a.get("s"), Duration::from_millis(7));
        assert_eq!(a.get("t"), Duration::from_millis(1));
    }
}
