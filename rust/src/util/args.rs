//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports the surface the `milo` binary and the examples need:
//! `--flag`, `--key value`, `--key=value`, positional arguments, and typed
//! accessors with defaults.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

/// Parsed command line: positionals + `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (tests) — flags listed in
    /// `bool_flags` consume no value.
    pub fn parse_from<I: IntoIterator<Item = String>>(
        tokens: I,
        bool_flags: &[&str],
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` separator: everything after is positional
                    args.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&rest) {
                    args.flags.push(rest.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{rest} expects a value"))?;
                    args.options.insert(rest.to_string(), v);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env(bool_flags: &[&str]) -> Result<Args> {
        Self::parse_from(std::env::args().skip(1), bool_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}={v} not an integer")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}={v} not an integer")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}={v} not a number")),
        }
    }

    /// Comma-separated list option, e.g. `--fractions 0.01,0.05,0.1`.
    pub fn get_list_f64(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<f64>()
                        .with_context(|| format!("--{key}: bad item {s:?}"))
                })
                .collect(),
        }
    }

    pub fn get_list_str(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse_from(
            toks("train --dataset cifar10 --fraction=0.1 --verbose x"),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["train", "x"]);
        assert_eq!(a.get("dataset"), Some("cifar10"));
        assert_eq!(a.get_f64("fraction", 0.0).unwrap(), 0.1);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse_from(toks("--dataset"), &[]).is_err());
    }

    #[test]
    fn defaults_and_lists() {
        let a = Args::parse_from(toks("--fractions 0.01,0.3"), &[]).unwrap();
        assert_eq!(a.get_list_f64("fractions", &[]).unwrap(), vec![0.01, 0.3]);
        assert_eq!(a.get_list_f64("other", &[1.0]).unwrap(), vec![1.0]);
        assert_eq!(a.get_usize("epochs", 17).unwrap(), 17);
        assert_eq!(a.get_or("name", "dflt"), "dflt");
    }

    #[test]
    fn double_dash_separator() {
        let a = Args::parse_from(toks("a -- --not-an-option"), &[]).unwrap();
        assert_eq!(a.positional, vec!["a", "--not-an-option"]);
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse_from(toks("--epochs abc"), &[]).unwrap();
        assert!(a.get_usize("epochs", 1).is_err());
    }
}
