//! Row-major f32 matrix used throughout the coordinator — feature tables,
//! similarity kernels, gradient embeddings. Deliberately minimal: the heavy
//! math happens either in the PJRT artifacts (L1/L2) or in cache-friendly
//! flat-slice loops in `submod`.

use anyhow::{bail, Result};

/// Dense row-major `rows x cols` f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Matrix {
        Matrix { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Matrix> {
        if data.len() != rows * cols {
            bail!("Matrix::from_vec: {}x{} != {}", rows, cols, data.len());
        }
        Ok(Matrix { rows, cols, data })
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Gather a sub-matrix of the given rows.
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (oi, &r) in idx.iter().enumerate() {
            out.row_mut(oi).copy_from_slice(self.row(r));
        }
        out
    }

    /// Copy `src` into rows starting at `at`.
    pub fn write_rows(&mut self, at: usize, src: &Matrix) {
        assert_eq!(self.cols, src.cols);
        assert!(at + src.rows <= self.rows);
        let start = at * self.cols;
        self.data[start..start + src.rows * self.cols]
            .copy_from_slice(&src.data);
    }

    /// L2-normalize every row in place (zero rows left untouched).
    pub fn l2_normalize_rows(&mut self) {
        for r in 0..self.rows {
            let row = self.row_mut(r);
            let n: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            if n > 1e-12 {
                for x in row.iter_mut() {
                    *x /= n;
                }
            }
        }
    }

    /// `self @ other^T` (naive blocked loop — used only by the native
    /// similarity fallback and tests; the hot path goes through PJRT).
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut out = Matrix::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let a = self.row(i);
            let orow = out.row_mut(i);
            for (j, o) in orow.iter_mut().enumerate() {
                // 4 independent accumulators so LLVM vectorizes the
                // reduction (a single serial accumulator defeats SIMD
                // because f32 addition is not associative) — §Perf L3.
                let b = other.row(j);
                let mut acc = [0.0f32; 4];
                let mut ac = a.chunks_exact(4);
                let mut bc = b.chunks_exact(4);
                for (ca, cb) in (&mut ac).zip(&mut bc) {
                    acc[0] += ca[0] * cb[0];
                    acc[1] += ca[1] * cb[1];
                    acc[2] += ca[2] * cb[2];
                    acc[3] += ca[3] * cb[3];
                }
                let mut tail = 0.0f32;
                for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
                    tail += x * y;
                }
                *o = acc[0] + acc[1] + acc[2] + acc[3] + tail;
            }
        }
        out
    }

    /// Mean of all entries.
    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64
    }
}

/// Read a little-endian f32 blob (the artifact `params/*.bin` layout).
pub fn read_f32_blob(path: &std::path::Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let mut m = Matrix::zeros(3, 4);
        m.set(1, 2, 5.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Matrix::from_vec(2, 2, vec![1.0; 3]).is_err());
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(m.at(1, 0), 3.0);
    }

    #[test]
    fn gather_and_write_rows() {
        let m = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.data(), &[5., 6., 1., 2.]);
        let mut dst = Matrix::zeros(4, 2);
        dst.write_rows(1, &g);
        assert_eq!(dst.row(1), &[5., 6.]);
        assert_eq!(dst.row(2), &[1., 2.]);
        assert_eq!(dst.row(0), &[0., 0.]);
    }

    #[test]
    fn normalize_rows() {
        let mut m = Matrix::from_vec(2, 2, vec![3., 4., 0., 0.]).unwrap();
        m.l2_normalize_rows();
        assert!((m.at(0, 0) - 0.6).abs() < 1e-6);
        assert!((m.at(0, 1) - 0.8).abs() < 1e-6);
        assert_eq!(m.row(1), &[0., 0.]); // zero row untouched
    }

    #[test]
    fn matmul_nt_small() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Matrix::from_vec(2, 3, vec![1., 0., 0., 0., 1., 0.]).unwrap();
        let c = a.matmul_nt(&b);
        assert_eq!(c.rows, 2);
        assert_eq!(c.cols, 2);
        assert_eq!(c.data(), &[1., 2., 4., 5.]);
    }

    #[test]
    fn blob_roundtrip() {
        let dir = std::env::temp_dir().join("milo_test_blob");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32_blob(&p).unwrap(), vals);
    }
}
