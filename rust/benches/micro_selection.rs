//! Micro-benchmarks of the L3 hot paths (the §Perf targets):
//!   * similarity-kernel construction (native vs PJRT/Pallas),
//!   * greedy maximization (naive vs lazy vs stochastic),
//!   * GreedySampleImportance (the WRE sweep),
//!   * weighted sampling (the per-epoch WRE select),
//!   * the PJRT train-step call itself.
//!
//! Run: `cargo bench --bench micro_selection`

use milo::kernel::{native_similarity, pjrt_similarity, SimMetric};
use milo::runtime::Runtime;
use milo::submod::{
    greedy_maximize, sample_importance, weighted_sample_without_replacement,
    FacilityLocation, GreedyMode, SetFunctionKind,
};
use milo::testkit::{bench, random_embeddings, random_kernel};
use milo::util::rng::Rng;

fn main() {
    let n = 512;
    let k = 64;
    let kernel = random_kernel(n, 1);
    let emb = random_embeddings(n, 32, 2);

    bench("native_similarity_512x32", 1, 10, || {
        native_similarity(&emb, SimMetric::Cosine)
    });

    if let Ok(rt) = Runtime::open("artifacts") {
        bench("pjrt_pallas_similarity_512x32", 1, 10, || {
            pjrt_similarity(&rt, &emb, SimMetric::Cosine).unwrap()
        });
        // train-step latency (the trainer's inner loop)
        let ds = milo::data::DatasetId::Cifar10Like.generate(1);
        let mut model =
            milo::train::model::MlpModel::load(&rt, "cifar10", 128, 1).unwrap();
        let idx: Vec<usize> = (0..128).collect();
        let hp = milo::train::StepHparams {
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 5e-4,
            nesterov: true,
        };
        bench("pjrt_train_step_b128_h128", 3, 50, || {
            model.train_step(&rt, &ds, &idx, hp).unwrap()
        });
        let idx1: Vec<usize> = (0..1).collect();
        bench("pjrt_train_step_b128_pad1", 3, 50, || {
            model.train_step(&rt, &ds, &idx1, hp).unwrap()
        });
    } else {
        eprintln!("artifacts missing: PJRT benches skipped");
    }

    let mut rng = Rng::new(3);
    bench("greedy_naive_fl_512_k64", 1, 5, || {
        let mut f = FacilityLocation::new(&kernel);
        greedy_maximize(&mut f, k, GreedyMode::Naive, true, &mut rng)
    });
    bench("greedy_lazy_fl_512_k64", 1, 5, || {
        let mut f = FacilityLocation::new(&kernel);
        greedy_maximize(&mut f, k, GreedyMode::Lazy, true, &mut rng)
    });
    bench("greedy_stochastic_fl_512_k64", 1, 5, || {
        let mut f = FacilityLocation::new(&kernel);
        greedy_maximize(&mut f, k, GreedyMode::Stochastic { epsilon: 0.01 }, true, &mut rng)
    });
    bench("sample_importance_dm_512", 1, 5, || {
        let mut f = SetFunctionKind::DisparityMin.build(&kernel);
        sample_importance(f.as_mut(), true)
    });
    bench("sample_importance_gc_512", 1, 5, || {
        let mut f = SetFunctionKind::GRAPH_CUT_DEFAULT.build(&kernel);
        sample_importance(f.as_mut(), true)
    });
    let weights: Vec<f64> = (0..5000).map(|i| 1.0 + (i % 17) as f64).collect();
    bench("weighted_sample_5000_k500", 2, 20, || {
        weighted_sample_without_replacement(&weights, 500, &mut rng)
    });
}
