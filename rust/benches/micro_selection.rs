//! Micro-benchmarks of the L3 hot paths (the §Perf targets):
//!   * similarity-kernel construction (native vs PJRT/Pallas),
//!   * greedy maximization (naive vs lazy vs stochastic),
//!   * GreedySampleImportance (the WRE sweep),
//!   * weighted sampling (the per-epoch WRE select),
//!   * the PJRT train-step call itself,
//!   * metadata-store cache-hit load vs a full preprocessing pass (the
//!     amortization ratio behind the paper's "no additional cost" claim),
//!   * MiloSession (builder API) vs a hand-wired pipeline: subset delivery
//!     through the session layer must cost the same as wiring
//!     Metadata→MiloStrategy by hand (asserted, not just printed),
//!   * serve wire modes: bytes and latency per `NEXT_SUBSET` over the
//!     JSON-line protocol vs the binary frame mode (binary must transfer
//!     strictly fewer bytes per request — asserted),
//!   * serve request latency under concurrent clients: per-frame-type
//!     round-trip p50/p99 (obs histograms client-side, cross-checked
//!     against the server's own `STATS` summaries), plus the overhead of
//!     the telemetry layer itself — `NEXT_SUBSET` timed with
//!     observability on vs `milo::obs::set_enabled(false)`, asserted
//!     within 5% in full mode, and likewise the always-on flight
//!     recorder against its own kill switch (with a live tail-sampling
//!     check and a `trace.jsonl` dump) — emitted as `BENCH_serve.json`,
//!   * preprocessing end-to-end over the synthetic 10-class bench
//!     dataset: dense vs sparse top-knn kernels at knn ∈ {32, 128, full}
//!     (wall-time per stage + stored kernel floats), emitted as
//!     `BENCH_select.json` so the perf trajectory accumulates across
//!     PRs. Asserted: knn=full selections are identical to dense, and
//!     knn=32 stores ≥ 4× fewer kernel floats; the ≥ 2× end-to-end
//!     speedup is asserted in full mode (CI runs `MILO_BENCH_SMOKE=1`,
//!     which confines the binary to the three JSON-emitting benches and
//!     skips the wall-clock asserts — timings in shared CI runners are
//!     noise),
//!   * the overlapped kernel-build pipeline: serial (`depth = 1`) vs
//!     double-buffered strip builds across produce/consume balances,
//!     with per-stage busy times and the device-idle fraction, emitted
//!     as the `"overlap"` section of `BENCH_select.json`; bit-identity
//!     of the two builds is asserted every run, and full mode asserts
//!     the best-balanced config is ≥ 1.3× faster than serial,
//!   * the continual-arrival path: per arrival batch, an incremental
//!     `ContinualSelector::advance_epoch` vs a from-scratch batch rebuild
//!     over the concatenated prefix (bit-identity of the two asserted
//!     every wave), emitted as `BENCH_stream.json`; full mode asserts the
//!     incremental path is ≥ 2× faster across the drift waves.
//!
//! Run: `cargo bench --bench micro_selection`

use milo::kernel::{native_similarity, pjrt_similarity, SimMetric};
use milo::runtime::Runtime;
use milo::submod::{
    greedy_maximize, sample_importance, weighted_sample_without_replacement,
    FacilityLocation, GreedyMode, SetFunctionKind,
};
use milo::testkit::{bench, random_embeddings, random_kernel};
use milo::util::rng::Rng;

fn main() {
    // CI smoke mode runs ONLY the three benches that emit JSON documents
    // (BENCH_select.json, BENCH_serve.json, BENCH_stream.json): the other
    // benches are full-size micro-benchmarks with wall-clock asserts that
    // have no business on a noisy shared runner.
    if std::env::var("MILO_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false) {
        bench_preprocess_select();
        bench_serve();
        bench_stream();
        return;
    }

    let n = 512;
    let k = 64;
    let kernel = random_kernel(n, 1);
    let emb = random_embeddings(n, 32, 2);

    bench("native_similarity_512x32", 1, 10, || {
        native_similarity(&emb, SimMetric::Cosine)
    });

    if let Ok(rt) = Runtime::open("artifacts") {
        bench("pjrt_pallas_similarity_512x32", 1, 10, || {
            pjrt_similarity(&rt, &emb, SimMetric::Cosine).unwrap()
        });
        // train-step latency (the trainer's inner loop)
        let ds = milo::data::DatasetId::Cifar10Like.generate(1);
        let mut model =
            milo::train::model::MlpModel::load(&rt, "cifar10", 128, 1).unwrap();
        let idx: Vec<usize> = (0..128).collect();
        let hp = milo::train::StepHparams {
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 5e-4,
            nesterov: true,
        };
        bench("pjrt_train_step_b128_h128", 3, 50, || {
            model.train_step(&rt, &ds, &idx, hp).unwrap()
        });
        let idx1: Vec<usize> = (0..1).collect();
        bench("pjrt_train_step_b128_pad1", 3, 50, || {
            model.train_step(&rt, &ds, &idx1, hp).unwrap()
        });
    } else {
        eprintln!("artifacts missing: PJRT benches skipped");
    }

    let mut rng = Rng::new(3);
    bench("greedy_naive_fl_512_k64", 1, 5, || {
        let mut f = FacilityLocation::new(&kernel);
        greedy_maximize(&mut f, k, GreedyMode::Naive, true, &mut rng)
    });
    bench("greedy_lazy_fl_512_k64", 1, 5, || {
        let mut f = FacilityLocation::new(&kernel);
        greedy_maximize(&mut f, k, GreedyMode::Lazy, true, &mut rng)
    });
    bench("greedy_stochastic_fl_512_k64", 1, 5, || {
        let mut f = FacilityLocation::new(&kernel);
        greedy_maximize(&mut f, k, GreedyMode::Stochastic { epsilon: 0.01 }, true, &mut rng)
    });
    bench("sample_importance_dm_512", 1, 5, || {
        let mut f = SetFunctionKind::DisparityMin.build(&kernel);
        sample_importance(f.as_mut(), true)
    });
    bench("sample_importance_gc_512", 1, 5, || {
        let mut f = SetFunctionKind::GRAPH_CUT_DEFAULT.build(&kernel);
        sample_importance(f.as_mut(), true)
    });
    let weights: Vec<f64> = (0..5000).map(|i| 1.0 + (i % 17) as f64).collect();
    bench("weighted_sample_5000_k500", 2, 20, || {
        weighted_sample_without_replacement(&weights, 500, &mut rng)
    });

    bench_store_amortization();
    bench_session_vs_handwired();
    bench_wire_modes();
    bench_serve();
    bench_preprocess_select();
    bench_stream();
}

/// Continual-arrival maintenance vs from-scratch rebuild: a seed wave
/// stripes all classes, then drift waves land in two classes each (the
/// realistic stream: most classes idle per epoch). Each wave is timed
/// twice — the incremental `advance_epoch` and a full batch rebuild over
/// the concatenated prefix — and the two are asserted **bit-identical**
/// every wave (the continual module's core contract, exercised here at
/// bench scale). The fraction stays fixed, so clean classes keep their
/// proportional budgets and the revision-keyed selection caches hit.
/// Results land in `BENCH_stream.json`; full mode asserts the
/// incremental path is ≥ 2× faster summed over the drift waves.
fn bench_stream() {
    use milo::continual::{ContinualOptions, ContinualSelector};
    use milo::coordinator::{
        fixed_subset_from_kernels, sge_subsets_from_kernels,
        wre_distribution_from_kernels,
    };
    use milo::kernel::{build_class_kernels, SimilarityBackend};
    use milo::util::json::Json;
    use std::time::Instant;

    let smoke = std::env::var("MILO_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let (n0, waves, batch, dim) = if smoke { (600, 4, 120, 8) } else { (4000, 8, 400, 16) };
    let classes = 10usize;
    let knn = 32usize;

    let mut opts = ContinualOptions::new("bench-stream");
    opts.knn = Some(knn);
    opts.fraction = 0.1;
    let (sge_fn, wre_fn, n_sge, epsilon, seed) = (
        opts.sge_function,
        opts.wre_function,
        opts.n_sge_subsets,
        opts.epsilon,
        opts.seed,
    );
    let z = random_embeddings(n0 + waves * batch, dim, 77);

    let mut sel = ContinualSelector::new(opts);
    // the batch baseline's class partition, mirrored in arrival order
    let mut partition: Vec<Vec<usize>> = vec![Vec::new(); classes];
    let mut next = 0usize;
    let (mut inc_drift_s, mut full_drift_s) = (0.0f64, 0.0f64);
    let mut per_wave = Vec::new();
    for w in 0..=waves {
        let count = if w == 0 { n0 } else { batch };
        for j in 0..count {
            let c = if w == 0 {
                next % classes
            } else if j % 2 == 0 {
                w % classes
            } else {
                (w + 3) % classes
            };
            partition[c].push(next);
            sel.arrive(c, z.row(next)).unwrap();
            next += 1;
        }

        let t0 = Instant::now();
        let (meta, stats) = sel.advance_epoch().unwrap();
        let inc_s = t0.elapsed().as_secs_f64();

        // from-scratch baseline over the concatenated prefix
        let t1 = Instant::now();
        let prefix: Vec<usize> = (0..next).collect();
        let zp = z.gather_rows(&prefix);
        let kernels = build_class_kernels(
            None,
            &zp,
            &partition,
            SimMetric::Cosine,
            SimilarityBackend::Native,
            Some(knn),
        )
        .unwrap();
        let k = ((0.1 * next as f64).round() as usize).max(1);
        let mut rng = Rng::new(seed ^ 0x9E1E_C7).derive_str("bench-stream");
        let sge =
            sge_subsets_from_kernels(next, &kernels, sge_fn, k, n_sge, epsilon, &mut rng);
        let wre = wre_distribution_from_kernels(&kernels, wre_fn);
        let fixed = fixed_subset_from_kernels(next, &kernels, wre_fn, k);
        let full_s = t1.elapsed().as_secs_f64();

        assert_eq!(meta.sge_subsets, sge, "wave {w}: incremental SGE diverged");
        assert_eq!(meta.wre_classes, wre, "wave {w}: incremental WRE diverged");
        assert_eq!(meta.fixed_dm, fixed, "wave {w}: incremental fixed subset diverged");

        if w > 0 {
            inc_drift_s += inc_s;
            full_drift_s += full_s;
        }
        println!(
            "bench stream[wave {w:>2}]  n {next:>5}  dirty {:>2}/{classes}  \
             sge recomputed {:>2}/{:<2}  incremental {:>7.1}ms  rebuild {:>7.1}ms",
            stats.dirty_classes,
            stats.sge_recomputed,
            stats.sge_jobs,
            inc_s * 1e3,
            full_s * 1e3,
        );
        per_wave.push(Json::obj(vec![
            ("wave", Json::num(w as f64)),
            ("n_train", Json::num(next as f64)),
            ("dirty_classes", Json::num(stats.dirty_classes as f64)),
            ("sge_recomputed", Json::num(stats.sge_recomputed as f64)),
            ("wre_recomputed", Json::num(stats.wre_recomputed as f64)),
            ("fixed_recomputed", Json::num(stats.fixed_recomputed as f64)),
            ("kernel_bytes", Json::num(stats.kernel_bytes as f64)),
            ("incremental_s", Json::num(inc_s)),
            ("full_rebuild_s", Json::num(full_s)),
        ]));
    }

    let speedup = full_drift_s / inc_drift_s.max(1e-12);
    println!(
        "bench stream: drift waves incremental {:.1}ms vs full rebuild {:.1}ms \
         ({speedup:.2}x)",
        inc_drift_s * 1e3,
        full_drift_s * 1e3,
    );
    if !smoke {
        assert!(
            speedup >= 2.0,
            "incremental maintenance must beat full rebuild ≥ 2x across drift \
             waves, got {speedup:.2}x"
        );
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("stream")),
        ("smoke", Json::Bool(smoke)),
        ("classes", Json::num(classes as f64)),
        ("embed_dim", Json::num(dim as f64)),
        ("knn", Json::num(knn as f64)),
        ("seed_points", Json::num(n0 as f64)),
        ("batch", Json::num(batch as f64)),
        ("drift_waves", Json::num(waves as f64)),
        ("per_wave", Json::arr(per_wave)),
        ("incremental_drift_s", Json::num(inc_drift_s)),
        ("full_rebuild_drift_s", Json::num(full_drift_s)),
        ("speedup_drift", Json::num(speedup)),
        ("bit_identical", Json::Bool(true)),
    ]);
    std::fs::write("BENCH_stream.json", doc.to_string()).unwrap();
    println!("bench stream: wrote BENCH_stream.json");
}

/// End-to-end serve latency under concurrent clients: N frame-wire
/// clients drive `NEXT_SUBSET` / `SAMPLE_WRE` / `GET_META` rounds against
/// one event-loop server, recording client-side round-trip latency per
/// frame type into [`milo::obs::Histogram`]s (the same bucket scheme the
/// server's own `serve.request_latency_ns.*` histograms use — the `STATS`
/// summaries are captured alongside for cross-checking). Then the cost of
/// the telemetry layer itself is measured, not assumed: `NEXT_SUBSET`
/// draws are timed with observability enabled vs
/// `milo::obs::set_enabled(false)`, and full mode asserts the
/// instrumented path stays within 5% of the uninstrumented baseline.
/// The always-on flight recorder gets the same treatment with its own
/// kill switch (`milo::obs::flight::set_enabled`) and the same 5% bar,
/// and tail-sampling is demonstrated live: with `MILO_TRACE` unset, one
/// draw past a lowered slow threshold must land its trace in the sample
/// buffer, and the recorder's dump is written to `trace.jsonl` for the
/// `milo trace` renderer. A scale sweep then holds tiers of idle
/// connections open (64 →
/// thousands, fd-budget-clamped) and records PING p50/p99 at each
/// occupancy. Results land in `BENCH_serve.json`.
fn bench_serve() {
    use milo::data::DatasetId;
    use milo::obs::Histogram;
    use milo::serve::{ClientOptions, ServeClient, SubsetServer, WireMode};
    use milo::util::json::Json;
    use std::sync::Arc;
    use std::time::Instant;

    let smoke = std::env::var("MILO_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let (n_clients, rounds) = if smoke { (4usize, 50usize) } else { (8, 400) };

    let ds = DatasetId::Trec6Like.generate(1);
    let meta = Arc::new(milo::testkit::synthetic_metadata(&ds, 0.1));
    let wre_k = ds.subset_size(0.05).max(1);
    let server = SubsetServer::bind("127.0.0.1:0", meta, None, 1).unwrap();
    let addr = server.addr().to_string();

    // one merged histogram per instrumented frame type; clients record
    // into locals and merge on exit (Histogram::merge is atomic)
    const FRAMES: [&str; 3] = ["next_subset", "sample_wre", "get_meta"];
    let merged: Vec<Histogram> = (0..FRAMES.len()).map(|_| Histogram::new()).collect();
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let addr = &addr;
            let merged = &merged;
            scope.spawn(move || {
                let mut client = ServeClient::connect_with(
                    addr,
                    &format!("bench-serve-{c}"),
                    ClientOptions { wire: WireMode::Frame, ..Default::default() },
                )
                .unwrap();
                let local: Vec<Histogram> =
                    (0..FRAMES.len()).map(|_| Histogram::new()).collect();
                for r in 0..rounds {
                    let t0 = Instant::now();
                    std::hint::black_box(client.next_subset().unwrap());
                    local[0].record_duration(t0.elapsed());
                    let t1 = Instant::now();
                    std::hint::black_box(client.sample_wre(wre_k).unwrap());
                    local[1].record_duration(t1.elapsed());
                    if r % 10 == 0 {
                        let t2 = Instant::now();
                        std::hint::black_box(client.get_meta().unwrap());
                        local[2].record_duration(t2.elapsed());
                    }
                }
                for (m, l) in merged.iter().zip(&local) {
                    m.merge(l);
                }
            });
        }
    });

    // the server's own view: per-frame-type latency summaries over STATS
    let mut probe = ServeClient::connect(&addr, "bench-serve-probe").unwrap();
    let stats = probe.stats().unwrap();
    let server_metrics = stats.get("metrics").unwrap().clone();
    let served_next = server_metrics
        .get("serve.request_latency_ns.next_subset")
        .and_then(|s| s.get("count"))
        .and_then(|c| c.as_f64())
        .unwrap();
    assert!(
        served_next >= (n_clients * rounds) as f64,
        "server counted {served_next} NEXT_SUBSET latencies, expected at least {}",
        n_clients * rounds,
    );

    for (name, h) in FRAMES.iter().zip(&merged) {
        let s = h.snapshot();
        println!(
            "bench serve[{name:>11}]  {:>6} requests  p50 {:>7.1}us  p99 {:>7.1}us  \
             max {:>8.1}us",
            s.count(),
            s.percentile(0.50) as f64 / 1e3,
            s.percentile(0.99) as f64 / 1e3,
            s.max() as f64 / 1e3,
        );
    }

    // instrumentation overhead, measured: the same client, the same
    // request stream, observability on vs off
    let mut measure = |draws: usize| -> f64 {
        let t0 = Instant::now();
        for _ in 0..draws {
            std::hint::black_box(probe.next_subset().unwrap());
        }
        t0.elapsed().as_secs_f64() / draws as f64
    };
    let draws = if smoke { 100 } else { 2000 };
    measure(draws); // warmup
    let with_obs = measure(draws);
    milo::obs::set_enabled(false);
    let without_obs = measure(draws);
    milo::obs::set_enabled(true);
    let ratio = with_obs / without_obs.max(1e-12);
    println!(
        "bench serve: NEXT_SUBSET {:.2}us/draw instrumented vs {:.2}us/draw with \
         obs disabled ({ratio:.3}x)",
        with_obs * 1e6,
        without_obs * 1e6,
    );
    if !smoke {
        // the acceptance bar: telemetry must cost < 5% on the hot serve
        // path (plus 5us absolute slack for scheduler noise at this scale)
        assert!(
            with_obs <= without_obs * 1.05 + 5e-6,
            "instrumented NEXT_SUBSET path exceeds the 5% overhead budget: \
             {with_obs}s vs {without_obs}s per draw"
        );
    }

    // the always-on flight recorder's marginal cost, same kill-switch
    // methodology: obs stays at its default (on), only the flight ring
    // toggles — so this isolates the recorder, not the whole layer
    let with_flight = measure(draws);
    milo::obs::flight::set_enabled(false);
    let without_flight = measure(draws);
    milo::obs::flight::set_enabled(true);
    let flight_ratio = with_flight / without_flight.max(1e-12);
    println!(
        "bench serve: NEXT_SUBSET {:.2}us/draw with flight recorder vs \
         {:.2}us/draw with it disabled ({flight_ratio:.3}x)",
        with_flight * 1e6,
        without_flight * 1e6,
    );
    if !smoke {
        assert!(
            with_flight <= without_flight * 1.05 + 5e-6,
            "flight recorder exceeds the 5% overhead budget on NEXT_SUBSET: \
             {with_flight}s vs {without_flight}s per draw"
        );
    }

    // tail-sampling, demonstrated: with MILO_TRACE unset (the normal
    // case — skip the demo rather than fight a configured sink), drop
    // the slow threshold to 1us so the next draw counts as slow, and
    // assert its trace shows up in the flight recorder's sample buffer
    let mut flight_sampled = false;
    if std::env::var("MILO_TRACE").is_err() {
        let sampled_before = milo::obs::flight::stats().sampled;
        let old_thresh = milo::obs::flight::slow_threshold_us();
        milo::obs::flight::set_slow_threshold_us(1);
        std::hint::black_box(probe.next_subset().unwrap());
        milo::obs::flight::set_slow_threshold_us(old_thresh);
        let (trace, echoed) = probe
            .last_trace()
            .expect("trace-capable server: requests are stamped");
        assert!(echoed, "JSON-wire control reply must echo the trace id");
        let stats = milo::obs::flight::stats();
        assert!(
            stats.sampled > sampled_before,
            "a request past the slow threshold must tail-sample \
             ({} before, {} after)",
            sampled_before,
            stats.sampled,
        );
        flight_sampled = milo::obs::flight::samples()
            .iter()
            .any(|s| s.trace == trace);
        assert!(
            flight_sampled,
            "the slow request's trace {} is missing from the sample buffer",
            milo::obs::id_hex(trace),
        );
        println!(
            "bench serve: slow-request trace {} tail-sampled with MILO_TRACE \
             unset ({} sample(s) buffered)",
            milo::obs::id_hex(trace),
            milo::obs::flight::samples().len(),
        );
    }

    // persist the recorder's view of this run for the CI artifact: ring
    // contents + tail-samples, schema-v2 JSON lines (`milo trace` input)
    std::fs::write("trace.jsonl", milo::obs::flight::dump_jsonl()).unwrap();

    // scale sweep: small-request latency as a function of *held-open*
    // connections — the fleet-scale serving curve (the soak tests prove
    // correctness at this occupancy; this records what it costs). Each
    // tier holds N idle JSON-line connections and measures PING
    // round-trips sampled across the fleet. Tiers clamp to the fd budget
    // (two fds per in-process connection); CI raises `ulimit -n` so the
    // thousands tiers run for real.
    let fd_budget = std::fs::read_to_string("/proc/self/limits")
        .ok()
        .and_then(|text| {
            text.lines()
                .find(|l| l.starts_with("Max open files"))
                .and_then(|l| l.split_whitespace().nth(3))
                .and_then(|v| v.parse::<u64>().ok())
        })
        .map(|soft| (soft.saturating_sub(100) / 2) as usize)
        .unwrap_or(usize::MAX);
    // MILO_BENCH_SCALE_FULL=1 upgrades just this sweep to the full tiers
    // while smoke mode keeps the noisy wall-clock asserts off — how the
    // CI soak job records the thousands-of-connections curve
    let scale_full = std::env::var("MILO_BENCH_SCALE_FULL")
        .map(|v| v == "1")
        .unwrap_or(false);
    let tiers: &[usize] =
        if smoke && !scale_full { &[16, 64] } else { &[64, 256, 1024, 2048] };
    let mut scale_rows = Vec::new();
    for &target in tiers {
        use std::io::{BufRead, BufReader, Write};
        let n = target.min(fd_budget).max(1);
        let mut conns = Vec::with_capacity(n);
        let mut line = String::new();
        for c in 0..n {
            let mut sock = std::net::TcpStream::connect(&addr).unwrap();
            sock.set_nodelay(true).unwrap();
            let mut reader = BufReader::new(sock.try_clone().unwrap());
            sock.write_all(
                format!("{{\"cmd\":\"HELLO\",\"client\":\"scale-{target}-{c}\"}}\n")
                    .as_bytes(),
            )
            .unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("\"ok\":true"), "scale HELLO failed: {line:?}");
            conns.push((sock, reader));
        }
        let h = Histogram::new();
        let probes = if smoke { 100usize } else { 400 };
        let step = (n / 16).max(1) | 1; // odd stride walks every residue
        let mut at = 0usize;
        for _ in 0..probes {
            let (sock, reader) = &mut conns[at % n];
            at += step;
            let t0 = Instant::now();
            sock.write_all(b"{\"cmd\":\"PING\"}\n").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            h.record_duration(t0.elapsed());
        }
        let s = h.snapshot();
        println!(
            "bench serve[scale]  {n:>5} conns held  ping p50 {:>7.1}us  \
             p99 {:>7.1}us  max {:>8.1}us",
            s.percentile(0.50) as f64 / 1e3,
            s.percentile(0.99) as f64 / 1e3,
            s.max() as f64 / 1e3,
        );
        scale_rows.push(Json::obj(vec![
            ("connections", Json::num(n as f64)),
            ("ping_probes", Json::num(s.count() as f64)),
            ("ping_p50_us", Json::num(s.percentile(0.50) as f64 / 1e3)),
            ("ping_p99_us", Json::num(s.percentile(0.99) as f64 / 1e3)),
            ("ping_max_us", Json::num(s.max() as f64 / 1e3)),
        ]));
        drop(conns);
    }

    let frames_json = Json::arr(
        FRAMES
            .iter()
            .zip(&merged)
            .map(|(name, h)| {
                let s = h.snapshot();
                Json::obj(vec![
                    ("frame", Json::str(*name)),
                    ("requests", Json::num(s.count() as f64)),
                    ("p50_us", Json::num(s.percentile(0.50) as f64 / 1e3)),
                    ("p99_us", Json::num(s.percentile(0.99) as f64 / 1e3)),
                    ("max_us", Json::num(s.max() as f64 / 1e3)),
                ])
            })
            .collect(),
    );
    let doc = Json::obj(vec![
        ("bench", Json::str("serve")),
        ("smoke", Json::Bool(smoke)),
        ("clients", Json::num(n_clients as f64)),
        ("rounds", Json::num(rounds as f64)),
        ("frames", frames_json),
        ("next_subset_us_with_obs", Json::num(with_obs * 1e6)),
        ("next_subset_us_without_obs", Json::num(without_obs * 1e6)),
        ("obs_overhead_ratio", Json::num(ratio)),
        ("next_subset_us_with_flight", Json::num(with_flight * 1e6)),
        ("next_subset_us_without_flight", Json::num(without_flight * 1e6)),
        ("flight_overhead_ratio", Json::num(flight_ratio)),
        ("flight_tail_sampled", Json::Bool(flight_sampled)),
        ("flight", milo::obs::flight::stats_json()),
        ("scale", Json::arr(scale_rows)),
        ("server_metrics", server_metrics),
    ]);
    std::fs::write("BENCH_serve.json", doc.to_string()).unwrap();
    println!("bench serve: wrote BENCH_serve.json");
    drop(probe);
    server.shutdown();
}

/// Dense vs sparse top-knn preprocessing over the synthetic 10-class
/// bench dataset: per-stage wall time (kernel build, SGE, WRE, fixed)
/// and stored kernel floats, written to `BENCH_select.json`. Runs
/// artifact-free (native backend over random embeddings).
fn bench_preprocess_select() {
    use milo::coordinator::{
        fixed_subset_from_kernels, sge_subsets_from_kernels,
        wre_distribution_from_kernels,
    };
    use milo::kernel::{build_class_kernels, SimilarityBackend};
    use milo::submod::SetFunctionKind;
    use milo::util::json::Json;
    use std::time::Instant;

    let smoke = std::env::var("MILO_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    // full mode sizes the greedy stages to dominate (the stages sparsity
    // accelerates); smoke keeps CI fast while still proving the memory
    // ratio and the knn=full equivalence
    let (per_class, embed_dim, n_sge) = if smoke { (320, 16, 3) } else { (512, 16, 16) };
    let classes = 10usize;
    let n = per_class * classes;
    let fraction = 0.1;
    let k = (fraction * n as f64).round() as usize;
    let sge_fn = SetFunctionKind::FacilityLocation;
    let wre_fn = SetFunctionKind::DisparityMin;
    let emb = random_embeddings(n, embed_dim, 42);
    let partition: Vec<Vec<usize>> = (0..classes)
        .map(|c| (c * per_class..(c + 1) * per_class).collect())
        .collect();

    struct Run {
        label: String,
        floats: usize,
        kernel_s: f64,
        sge_s: f64,
        wre_s: f64,
        fixed_s: f64,
        sge: Vec<Vec<usize>>,
        wre: Vec<milo::selection::milo::ClassProbs>,
        fixed: Vec<usize>,
    }

    let configs: Vec<(String, Option<usize>)> = vec![
        ("dense".to_string(), None),
        ("knn32".to_string(), Some(32)),
        ("knn128".to_string(), Some(128)),
        ("full".to_string(), Some(per_class)),
    ];
    let mut runs: Vec<Run> = Vec::new();
    for (label, knn) in configs {
        let t0 = Instant::now();
        let kernels = build_class_kernels(
            None,
            &emb,
            &partition,
            SimMetric::Cosine,
            SimilarityBackend::Native,
            knn,
        )
        .unwrap();
        let kernel_s = t0.elapsed().as_secs_f64();
        let mut rng = Rng::new(7);
        let t1 = Instant::now();
        let sge = sge_subsets_from_kernels(n, &kernels, sge_fn, k, n_sge, 0.01, &mut rng);
        let sge_s = t1.elapsed().as_secs_f64();
        let t2 = Instant::now();
        let wre = wre_distribution_from_kernels(&kernels, wre_fn);
        let wre_s = t2.elapsed().as_secs_f64();
        let t3 = Instant::now();
        let fixed = fixed_subset_from_kernels(n, &kernels, wre_fn, k);
        let fixed_s = t3.elapsed().as_secs_f64();
        let floats = kernels.total_elements();
        println!(
            "bench preprocess_select[{label:>6}]  kernel {:>7.1}ms  sge {:>7.1}ms  \
             wre {:>7.1}ms  fixed {:>6.1}ms  total {:>7.1}ms  ({floats} floats)",
            kernel_s * 1e3,
            sge_s * 1e3,
            wre_s * 1e3,
            fixed_s * 1e3,
            (kernel_s + sge_s + wre_s + fixed_s) * 1e3,
        );
        runs.push(Run { label, floats, kernel_s, sge_s, wre_s, fixed_s, sge, wre, fixed });
    }

    // knn ≥ n_c must reproduce the dense selections exactly — same RNG
    // stream, bit-identical gains, identical subsets
    let (dense, full) = (&runs[0], &runs[3]);
    assert_eq!(dense.sge, full.sge, "knn=full SGE subsets diverged from dense");
    assert_eq!(dense.fixed, full.fixed, "knn=full fixed subset diverged from dense");
    assert_eq!(dense.wre, full.wre, "knn=full WRE distributions diverged from dense");

    let total = |r: &Run| r.kernel_s + r.sge_s + r.wre_s + r.fixed_s;
    let knn32 = &runs[1];
    let memory_ratio = dense.floats as f64 / knn32.floats.max(1) as f64;
    let speedup = total(dense) / total(knn32).max(1e-12);
    println!(
        "bench preprocess_select: knn=32 stores {memory_ratio:.1}x fewer kernel \
         floats and preprocesses {speedup:.2}x faster end-to-end than dense"
    );
    assert!(
        memory_ratio >= 4.0,
        "knn=32 must store ≥ 4x fewer kernel floats than dense, got {memory_ratio:.2}x"
    );
    if !smoke {
        assert!(
            speedup >= 2.0,
            "knn=32 must preprocess ≥ 2x faster end-to-end than dense, got {speedup:.2}x"
        );
    }

    // --- overlapped kernel-build pipeline: serial vs double-buffered ---
    // One class block at a time (no par_map around it), so the producer
    // and consumer threads own their cores and the measured overlap is
    // the pipeline's, not the scheduler's. Configs span the
    // produce/consume balance; the ≥ 1.3x assert holds for the best one
    // (an unbalanced split caps the achievable overlap below 2x).
    use milo::kernel::sparse::sparse_native_scheduled;
    use milo::kernel::{KernelSchedule, PipelineStats};

    let (on, o_reps) = if smoke { (512usize, 2usize) } else { (2048, 5) };
    let o_knn = 32usize;
    let overlap_cfgs: Vec<(&str, SimMetric, usize)> = vec![
        ("cosine_e4", SimMetric::Cosine, 4),
        ("rbf_e8", SimMetric::Rbf { kw: 0.5 }, 8),
        ("rbf_e16", SimMetric::Rbf { kw: 0.5 }, 16),
    ];
    let mut overlap_rows: Vec<Json> = Vec::new();
    let mut best_speedup = 0.0f64;
    for (label, metric, e) in overlap_cfgs {
        let oz = random_embeddings(on, e, 43);
        // min-of-reps wall time (and that rep's stage stats): benches
        // want the undisturbed run, not the average over OS noise
        let time_sched = |sched: &KernelSchedule| {
            let mut wall = f64::MAX;
            let mut stats = PipelineStats::default();
            let mut kernel = None;
            for _ in 0..o_reps {
                let t0 = Instant::now();
                let (kr, st) = sparse_native_scheduled(&oz, metric, o_knn, sched).unwrap();
                let w = t0.elapsed().as_secs_f64();
                if w < wall {
                    wall = w;
                    stats = st;
                }
                kernel = Some(kr);
            }
            (kernel.unwrap(), wall, stats)
        };
        let (ks, serial_s, _) = time_sched(&KernelSchedule::serial());
        let (kp, piped_s, pst) = time_sched(&KernelSchedule::default());
        assert_eq!(ks, kp, "overlap[{label}]: pipelined kernel diverged from serial");
        let sp = serial_s / piped_s.max(1e-12);
        best_speedup = best_speedup.max(sp);
        println!(
            "bench overlap[{label:>9}]  serial {:>7.1}ms  depth2 {:>7.1}ms  \
             {sp:.2}x  (produce {:.1}ms  consume {:.1}ms  idle {:.2})",
            serial_s * 1e3,
            piped_s * 1e3,
            pst.produce_secs * 1e3,
            pst.consume_secs * 1e3,
            pst.device_idle_fraction(),
        );
        overlap_rows.push(Json::obj(vec![
            ("config", Json::str(label)),
            ("n", Json::num(on as f64)),
            ("embed_dim", Json::num(e as f64)),
            ("knn", Json::num(o_knn as f64)),
            ("serial_s", Json::num(serial_s)),
            ("pipelined_s", Json::num(piped_s)),
            ("produce_s", Json::num(pst.produce_secs)),
            ("consume_s", Json::num(pst.consume_secs)),
            ("stall_s", Json::num(pst.stall_secs)),
            ("device_idle_fraction", Json::num(pst.device_idle_fraction())),
            ("speedup", Json::num(sp)),
            ("bit_identical", Json::Bool(true)),
        ]));
    }
    if !smoke {
        assert!(
            best_speedup >= 1.3,
            "double-buffered kernel build must be ≥ 1.3x faster than serial \
             on its best-balanced config, got {best_speedup:.2}x"
        );
    }

    let config_json = |r: &Run| {
        Json::obj(vec![
            ("config", Json::str(r.label.clone())),
            ("kernel_floats", Json::num(r.floats as f64)),
            (
                "secs",
                Json::obj(vec![
                    ("kernel", Json::num(r.kernel_s)),
                    ("sge", Json::num(r.sge_s)),
                    ("wre", Json::num(r.wre_s)),
                    ("fixed", Json::num(r.fixed_s)),
                    ("total", Json::num(total(r))),
                ]),
            ),
        ])
    };
    let doc = Json::obj(vec![
        ("bench", Json::str("preprocess_select")),
        ("smoke", Json::Bool(smoke)),
        ("n_train", Json::num(n as f64)),
        ("classes", Json::num(classes as f64)),
        ("embed_dim", Json::num(embed_dim as f64)),
        ("fraction", Json::num(fraction)),
        ("n_sge_subsets", Json::num(n_sge as f64)),
        ("sge_function", Json::str(sge_fn.name())),
        ("wre_function", Json::str(wre_fn.name())),
        ("configs", Json::arr(runs.iter().map(config_json).collect())),
        ("memory_ratio_knn32", Json::num(memory_ratio)),
        ("speedup_knn32", Json::num(speedup)),
        ("full_matches_dense", Json::Bool(true)),
        (
            "overlap",
            Json::obj(vec![
                ("configs", Json::arr(overlap_rows)),
                ("best_speedup", Json::num(best_speedup)),
                ("asserted_min_speedup", Json::num(1.3)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_select.json", doc.to_string()).unwrap();
    println!("bench preprocess_select: wrote BENCH_select.json");
}

/// JSON-line vs binary-frame `NEXT_SUBSET`: draw the same deterministic
/// stream over both wire modes against one event-loop server and compare
/// bytes received per request (asserted strictly smaller for frames — the
/// subset index array travels as raw u32 words instead of decimal text)
/// plus round-trip latency.
fn bench_wire_modes() {
    use milo::data::DatasetId;
    use milo::serve::{ClientOptions, ServeClient, SubsetServer, WireMode};
    use std::sync::Arc;

    let ds = DatasetId::Trec6Like.generate(1);
    let meta = Arc::new(milo::testkit::synthetic_metadata(&ds, 0.1));
    let subset_len = meta.sge_subsets.first().map(|s| s.len()).unwrap_or(0);
    let server = SubsetServer::bind("127.0.0.1:0", meta, None, 1).unwrap();
    let addr = server.addr().to_string();

    let draws = 64u64;
    let mut per_request = Vec::new();
    for wire in [WireMode::Json, WireMode::Frame] {
        let mut client = ServeClient::connect_with(
            &addr,
            "bench-wire",
            ClientOptions { wire, ..Default::default() },
        )
        .unwrap();
        client.next_subset().unwrap(); // warmup
        let rx0 = client.bytes_received();
        let t0 = std::time::Instant::now();
        for _ in 0..draws {
            std::hint::black_box(client.next_subset().unwrap());
        }
        let secs = t0.elapsed().as_secs_f64();
        let rx = (client.bytes_received() - rx0) as f64 / draws as f64;
        println!(
            "bench serve_next_subset_{:5}  {:>8.1} B/request  {:>8.1} us/request \
             (subset of {subset_len})",
            wire.name(),
            rx,
            1e6 * secs / draws as f64,
        );
        per_request.push(rx);
    }
    server.shutdown();
    let (json_bytes, frame_bytes) = (per_request[0], per_request[1]);
    assert!(
        frame_bytes < json_bytes,
        "binary frames must transfer strictly fewer bytes per NEXT_SUBSET: \
         frame {frame_bytes} B vs json {json_bytes} B"
    );
}

/// Builder-vs-hand-wired subset delivery: drive `MiloStrategy::select`
/// through (a) a `MiloSession` (store source, cached resolution) and
/// (b) a hand-wired `Metadata` → `MiloStrategy` pipeline, and assert the
/// session layer adds no measurable overhead per delivered subset. Runs
/// without artifacts (synthetic metadata through a store).
fn bench_session_vs_handwired() {
    use milo::prelude::*;

    let dir = std::env::temp_dir()
        .join(format!("milo_bench_session_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = MetaStore::open(&dir).unwrap();

    let ds = DatasetId::Trec6Like.generate(1);
    let opts = PreprocessOptions {
        fraction: 0.1,
        backend: SimilarityBackend::Native,
        seed: 1,
        ..Default::default()
    };
    let k = ds.subset_size(0.1);
    let key = MetaKey::from_options(ds.name(), &opts);
    store
        .put(&key, milo::testkit::synthetic_metadata(&ds, 0.1))
        .unwrap();

    // (a) the session path
    let session = MiloSession::builder()
        .dataset(DatasetId::Trec6Like.generate(1))
        .source(MetaSource::store_handle(store.clone(), opts))
        .build()
        .unwrap();
    let mut session_strat =
        session.strategy(StrategyKind::Milo { kappa: 1.0 / 6.0 }).unwrap();

    // (b) the hand-wired path over the same artifact
    let handwired_meta = store.get_or_build(&key, || unreachable!()).unwrap();
    let mut handwired_strat = handwired_meta.milo_strategy(1.0 / 6.0);

    let epochs = 60usize;
    let time_deliveries = |strat: &mut dyn Strategy, ds: &Dataset| -> f64 {
        let mut rng = Rng::new(0xBE7C);
        // warmup
        for epoch in 0..epochs {
            let mut ctx = SelectCtx::model_agnostic(ds, epoch, epochs, k, &mut rng);
            std::hint::black_box(strat.select(&mut ctx).unwrap());
        }
        let iters = 20;
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            for epoch in 0..epochs {
                let mut ctx =
                    SelectCtx::model_agnostic(ds, epoch, epochs, k, &mut rng);
                std::hint::black_box(strat.select(&mut ctx).unwrap());
            }
        }
        t0.elapsed().as_secs_f64() / (iters * epochs) as f64
    };

    let handwired = time_deliveries(&mut handwired_strat, &ds);
    let via_session = time_deliveries(session_strat.as_mut(), session.dataset());
    println!(
        "bench session_vs_handwired: hand-wired {:.3}us/select, session {:.3}us/select \
         ({:.2}x)",
        handwired * 1e6,
        via_session * 1e6,
        via_session / handwired.max(1e-12),
    );
    // "no measurable overhead": same strategy object underneath, so allow
    // only scheduler noise — 25% relative or 20us absolute, whichever is
    // larger. (Never runs under MILO_BENCH_SMOKE — main() confines smoke
    // runs to the preprocessing bench.)
    assert!(
        via_session <= handwired * 1.25 + 20e-6,
        "session layer added measurable subset-delivery overhead: \
         {via_session}s vs {handwired}s per select"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Store amortization: once metadata is in the content-addressed store, a
/// consumer pays a cache-hit load (or one binary decode) instead of a full
/// `Preprocessor::run`. With artifacts present this prints the measured
/// ratio; without, it still benches the encode/decode hot path over
/// synthetic metadata.
fn bench_store_amortization() {
    use milo::store::{MetaKey, MetaStore};

    let dir = std::env::temp_dir()
        .join(format!("milo_bench_store_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = MetaStore::open(&dir).unwrap();

    let (key, meta, full_secs) = if let Ok(rt) = Runtime::open("artifacts") {
        let ds = milo::data::DatasetId::Trec6Like.generate(1);
        let pre = milo::coordinator::Preprocessor::with_options(
            &rt,
            milo::coordinator::PreprocessOptions {
                fraction: 0.1,
                backend: milo::kernel::SimilarityBackend::Native,
                ..Default::default()
            },
        );
        let key = MetaKey::from_options(ds.name(), &pre.opts);
        let t0 = std::time::Instant::now();
        let meta = pre.run(&ds).unwrap();
        let full_secs = t0.elapsed().as_secs_f64();
        (key, meta, Some(full_secs))
    } else {
        eprintln!("artifacts missing: store bench uses synthetic metadata");
        let mut rng = Rng::new(11);
        let n = 5000;
        let meta = milo::coordinator::Metadata {
            dataset: "synthetic".into(),
            fraction: 0.1,
            sge_subsets: (0..3).map(|_| rng.sample_indices(n, n / 10)).collect(),
            wre_classes: (0..10)
                .map(|c| milo::selection::milo::ClassProbs {
                    indices: (c * n / 10..(c + 1) * n / 10).collect(),
                    probs: vec![10.0 / n as f64; n / 10],
                })
                .collect(),
            fixed_dm: rng.sample_indices(n, n / 10),
            preprocess_secs: 0.0,
        };
        let key = MetaKey::from_options(
            "synthetic",
            &milo::coordinator::PreprocessOptions::default(),
        );
        (key, meta, None)
    };

    store.put(&key, meta).unwrap();
    bench("store_lru_cache_hit", 2, 50, || {
        store
            .get_or_build(&key, || unreachable!("must be a cache hit"))
            .unwrap()
    });
    bench("store_cold_binary_decode", 2, 50, || {
        store.load_uncached(&key).unwrap().unwrap()
    });

    if let Some(full_secs) = full_secs {
        // measured amortization ratio: full pass vs warm cache hit
        let iters = 200;
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            std::hint::black_box(
                store.get_or_build(&key, || unreachable!()).unwrap(),
            );
        }
        let hit_secs = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "store amortization: full preprocess {:.3}s vs cache hit {:.6}s -> {:.0}x \
             (every additional consumer is ~free)",
            full_secs,
            hit_secs,
            full_secs / hit_secs.max(1e-12),
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
