//! Bench: regenerate paper Tables 1–2 (mean/median EL2N of subsets chosen
//! by each set function), plus the generator-hardness cross-check column.
//!
//! Run: `cargo bench --bench table_el2n`

use milo::coordinator::repro::{table_el2n, ReproOptions};
use milo::runtime::Runtime;

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts`");
            return;
        }
    };
    let opts = ReproOptions {
        out_dir: "results/bench".into(),
        verbose: false,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    for t in table_el2n(&rt, &opts).expect("el2n") {
        println!("{}", t.to_markdown());
    }
    println!("tables 1-2 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
