//! Bench: regenerate paper Fig. 4 (fixed-subset accuracy per set function
//! on CIFAR100-like at 10% and 30%).
//!
//! Run: `cargo bench --bench fig4_setfunctions`

use milo::coordinator::repro::{fig4_setfunctions, ReproOptions};
use milo::runtime::Runtime;

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts`");
            return;
        }
    };
    let opts = ReproOptions {
        epochs: 20,
        fractions: vec![0.1, 0.3],
        out_dir: "results/bench".into(),
        verbose: false,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    for t in fig4_setfunctions(&rt, &opts).expect("fig4") {
        println!("{}", t.to_markdown());
    }
    println!("fig4 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
