//! Bench: regenerate paper Table 9 (Kendall-τ hyper-parameter ordering
//! retention vs FULL tuning) with a reduced config grid.
//!
//! Run: `cargo bench --bench table_kendall`
//! Full-scale: `milo repro kendall --configs 108 --epochs 12`

use milo::coordinator::repro::{table_kendall, ReproOptions};
use milo::runtime::Runtime;

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts`");
            return;
        }
    };
    let opts = ReproOptions {
        epochs: 6,
        out_dir: "results/bench".into(),
        verbose: false,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    for t in table_kendall(&rt, &opts, 36).expect("kendall") {
        println!("{}", t.to_markdown());
    }
    println!("table 9 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
