//! Bench: the paper Fig. 7 / Table 10 scenario (HPO speedup-accuracy
//! tradeoff, Random+HB and TPE+HB) at a reduced budget, driven through
//! the `MiloSession` builder — one session resolution amortizes across
//! every tuner and both search algorithms.
//!
//! Run: `cargo bench --bench fig7_hpo`
//! Full-scale grid: `milo repro fig7 --epochs 27`

use milo::prelude::*;

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts`");
            return;
        }
    };
    let fraction = 0.05;
    let max_epochs = 9;
    // native backend: same preprocessing recipe the standalone Tuner used
    let session = MiloSession::builder()
        .runtime(&rt)
        .dataset(DatasetId::Trec6Like.generate(1))
        .source(MetaSource::inline(PreprocessOptions {
            backend: SimilarityBackend::Native,
            ..Default::default()
        }))
        .fraction(fraction)
        .seed(1)
        .build()
        .expect("session");

    let mut table = Table::new(
        "Fig 7 (bench budget): HPO tradeoff via MiloSession, trec6",
        &["search", "strategy", "best_test_acc_%", "tuning_secs", "speedup"],
    );
    let t0 = std::time::Instant::now();
    for algo in [SearchAlgo::Random, SearchAlgo::Tpe] {
        let full = session
            .tuner(HpoConfig {
                algo,
                strategy: StrategyKind::Full,
                fraction: 1.0,
                max_epochs,
                eta: 3,
                seed: 1,
            })
            .expect("full tuner")
            .run()
            .expect("full tuning");
        table.push(vec![
            algo.name().into(),
            "full".into(),
            format!("{:.2}", 100.0 * full.best_test_accuracy),
            format!("{:.2}", full.tuning_secs),
            "1.00".into(),
        ]);
        for kind in [
            StrategyKind::Milo { kappa: 1.0 / 6.0 },
            StrategyKind::MiloFixed,
            StrategyKind::AdaptiveRandom,
        ] {
            let out = session
                .tuner(HpoConfig {
                    algo,
                    strategy: kind,
                    fraction,
                    max_epochs,
                    eta: 3,
                    seed: 1,
                })
                .expect("tuner")
                .run()
                .expect("tuning");
            table.push(vec![
                algo.name().into(),
                kind.name().into(),
                format!("{:.2}", 100.0 * out.best_test_accuracy),
                format!("{:.2}", out.tuning_secs),
                format!("{:.2}", full.tuning_secs / out.tuning_secs.max(1e-9)),
            ]);
        }
    }
    println!("{}", table.to_markdown());
    table.save("results/bench", "fig7_hpo_session").expect("save");
    println!("fig7 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
