//! Bench: regenerate paper Fig. 7 / Table 10 (HPO speedup-accuracy
//! tradeoff, Random+HB and TPE+HB) at a reduced budget.
//!
//! Run: `cargo bench --bench fig7_hpo`

use milo::coordinator::repro::{fig7_hpo, ReproOptions};
use milo::runtime::Runtime;

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts`");
            return;
        }
    };
    let opts = ReproOptions {
        epochs: 9, // hyperband max resource
        fractions: vec![0.05, 0.3],
        out_dir: "results/bench".into(),
        verbose: false,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    for t in fig7_hpo(&rt, &opts).expect("fig7") {
        println!("{}", t.to_markdown());
    }
    println!("fig7 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
