//! Bench: regenerate paper Fig. 6 / Tables 5–8 (the main speedup vs
//! accuracy-degradation grid) on two datasets at a reduced budget.
//!
//! Run: `cargo bench --bench fig6_tradeoff`
//! Full-scale version: `milo repro fig6 --epochs 40 --seeds 1,2,3,4,5`

use milo::coordinator::repro::{fig6_tradeoff, ReproOptions};
use milo::data::DatasetId;
use milo::runtime::Runtime;

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts`");
            return;
        }
    };
    let opts = ReproOptions {
        epochs: 14,
        fractions: vec![0.05, 0.3],
        out_dir: "results/bench".into(),
        verbose: false,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let tables = fig6_tradeoff(
        &rt,
        &opts,
        &[DatasetId::RottenLike, DatasetId::Cifar10Like],
    )
    .expect("fig6");
    for t in &tables {
        println!("{}", t.to_markdown());
    }
    println!("fig6 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
