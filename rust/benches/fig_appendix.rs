//! Bench: regenerate the appendix figures added on top of the main grid —
//!   Fig 9 / App H.1 (specialized-domain datasets),
//!   Fig 11 (encoder-variant ablation),
//!   Ext A (Gibbs exchange chain, paper §3.1 Eq. 2 future work),
//!   Ext B (kernel-free feature-based MILO, conclusion future work).
//!
//! Run: `cargo bench --bench fig_appendix`

use milo::coordinator::repro::{
    ext_featurebased, ext_gibbs, fig11_encoders, fig9_specialized, ReproOptions,
};
use milo::runtime::Runtime;

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts`");
            return;
        }
    };
    let opts = ReproOptions {
        epochs: 16,
        out_dir: "results/bench".into(),
        verbose: false,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    for (name, tables) in [
        ("fig 9 / app h.1", fig9_specialized(&rt, &opts).expect("fig9")),
        ("fig 11", fig11_encoders(&rt, &opts).expect("fig11")),
        ("ext A: gibbs", ext_gibbs(&rt, &opts).expect("gibbs")),
        ("ext B: feature-based", ext_featurebased(&rt, &opts).expect("featspace")),
    ] {
        println!("==== {name} ====");
        for t in tables {
            println!("{}", t.to_markdown());
        }
    }
    println!("appendix figures regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
