//! Bench: regenerate paper Fig. 5 (a: SGE vs WRE vs Fixed across sizes;
//! b: early-convergence of SGE(GC) vs WRE(DM) vs SGE(FL) vs WRE(GC)).
//!
//! Run: `cargo bench --bench fig5_sge_wre`

use milo::coordinator::repro::{fig5a_sge_wre, fig5b_early_convergence, ReproOptions};
use milo::runtime::Runtime;

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts`");
            return;
        }
    };
    let opts = ReproOptions {
        epochs: 16,
        fractions: vec![0.05, 0.3],
        out_dir: "results/bench".into(),
        verbose: false,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    for t in fig5a_sge_wre(&rt, &opts).expect("fig5a") {
        println!("{}", t.to_markdown());
    }
    for t in fig5b_early_convergence(&rt, &opts).expect("fig5b") {
        println!("{}", t.to_markdown());
    }
    println!("fig5 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
