//! Bench: regenerate paper Fig. 1 (convergence per epoch and per
//! wall-clock for AdaptiveRandom / CraigPB / GradMatchPB at 10%, R=1)
//! at a reduced epoch budget, and time the per-selection cost gap that
//! drives the figure.
//!
//! Run: `cargo bench --bench fig1_convergence`

use milo::coordinator::repro::{fig1_convergence, ReproOptions};
use milo::runtime::Runtime;

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts`");
            return;
        }
    };
    let opts = ReproOptions {
        epochs: 16,
        out_dir: "results/bench".into(),
        verbose: false,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let tables = fig1_convergence(&rt, &opts).expect("fig1");
    for t in &tables {
        println!("{}", t.to_markdown());
    }
    println!("fig1 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
