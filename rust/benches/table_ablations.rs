//! Bench: regenerate the remaining ablation tables —
//!   Tables 11–12 (similarity metric), Table 13 (κ sweep),
//!   Table 14 (R sweep), Tables 15–16 (WRE vs SGE-variant),
//!   Table 17 (self-supervised pruning), App. H.3 (pre-processing time).
//!
//! Run: `cargo bench --bench table_ablations`

use milo::coordinator::repro::{
    preprocess_time, table_kappa, table_r, table_simmetric, table_ssl_prune,
    table_wre_variant, ReproOptions,
};
use milo::runtime::Runtime;

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts`");
            return;
        }
    };
    let opts = ReproOptions {
        epochs: 12,
        fractions: vec![0.05, 0.3],
        out_dir: "results/bench".into(),
        verbose: false,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    for (name, tables) in [
        ("tables 11-12", table_simmetric(&rt, &opts).expect("simmetric")),
        ("table 13", table_kappa(&rt, &opts).expect("kappa")),
        ("table 14", table_r(&rt, &opts).expect("r")),
        ("tables 15-16", table_wre_variant(&rt, &opts).expect("wre")),
        ("table 17", table_ssl_prune(&rt, &opts).expect("ssl")),
        ("app h3", preprocess_time(&rt, &opts).expect("preptime")),
    ] {
        println!("==== {name} ====");
        for t in tables {
            println!("{}", t.to_markdown());
        }
    }
    println!("ablations regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
