//! Reconnect semantics of `ServeClient`'s retry policy: kill and restart
//! the server mid-epoch and assert the client resumes the *exact* stream
//! an uninterrupted connection would have produced (the server streams
//! are pure functions of `(seed, entry, client id)`; the client
//! fast-forwards past everything it already consumed). Also covers the
//! give-up path (clear error once the budget is exhausted) and the
//! refuse-to-resume path (a restarted server with a different seed must
//! not be silently continued into).

use std::sync::Arc;

use milo::coordinator::Metadata;
use milo::data::DatasetId;
use milo::selection::WreStrategy;
use milo::serve::{
    client_start_cursor, client_stream_rng, ClientOptions, RetryPolicy, ServeClient,
    SubsetServer, WireMode,
};
use milo::testkit::synthetic_metadata;

const SEED: u64 = 9;
const WRE_K: usize = 16;
const ROUNDS: usize = 4;

fn meta() -> Arc<Metadata> {
    Arc::new(synthetic_metadata(&DatasetId::Trec6Like.generate(SEED), 0.1))
}

/// The uninterrupted reference stream (see `serve_stress.rs`).
fn inline_stream(
    meta: &Metadata,
    client: &str,
    rounds: usize,
) -> (Vec<(usize, Vec<usize>)>, Vec<Vec<usize>>) {
    let start = client_start_cursor(meta, client);
    let n = meta.sge_subsets.len();
    let sge = (0..rounds)
        .map(|i| {
            let idx = (start + i) % n;
            (idx, meta.sge_subsets[idx].clone())
        })
        .collect();
    let wre_inline = WreStrategy::new("inline", meta.wre_classes.clone());
    let mut rng = client_stream_rng(SEED, meta, client);
    let wre = (0..rounds).map(|_| wre_inline.sample_k(WRE_K, &mut rng)).collect();
    (sge, wre)
}

fn retrying_options(wire: WireMode) -> ClientOptions {
    ClientOptions {
        wire,
        retry: RetryPolicy { max_reconnects: 5, backoff_ms: 20 },
        ..Default::default()
    }
}

#[test]
fn server_restart_mid_epoch_resumes_the_stream_deterministically() {
    for wire in [WireMode::Json, WireMode::Frame] {
        let meta = meta();
        let server = SubsetServer::bind("127.0.0.1:0", meta.clone(), None, SEED).unwrap();
        let addr = server.addr().to_string();

        let mut client =
            ServeClient::connect_with(&addr, "trainer-restart", retrying_options(wire))
                .unwrap();
        let mut sge = Vec::new();
        let mut wre = Vec::new();
        // first half of the epoch against the original server
        for _ in 0..ROUNDS / 2 {
            sge.push(client.next_subset().unwrap());
            wre.push(client.sample_wre(WRE_K).unwrap());
        }

        // kill the server mid-epoch and restart it on the same address
        // (the listener carries SO_REUSEADDR exactly for this) with the
        // same artifact and seed
        server.shutdown();
        let server2 = SubsetServer::bind(&addr, meta.clone(), None, SEED).unwrap();

        // the client notices the dead transport on its next draw,
        // reconnects, replays, and hands out the *remaining* stream
        for _ in ROUNDS / 2..ROUNDS {
            sge.push(client.next_subset().unwrap());
            wre.push(client.sample_wre(WRE_K).unwrap());
        }

        let (expect_sge, expect_wre) = inline_stream(&meta, "trainer-restart", ROUNDS);
        assert_eq!(sge, expect_sge, "SGE stream diverged across restart ({wire:?})");
        assert_eq!(wre, expect_wre, "WRE stream diverged across restart ({wire:?})");
        server2.shutdown();
    }
}

#[test]
fn give_up_path_is_a_clear_error_after_the_retry_budget() {
    let meta = meta();
    let server = SubsetServer::bind("127.0.0.1:0", meta, None, SEED).unwrap();
    let addr = server.addr().to_string();
    let mut client = ServeClient::connect_with(
        &addr,
        "trainer-doomed",
        ClientOptions {
            retry: RetryPolicy { max_reconnects: 2, backoff_ms: 5 },
            ..Default::default()
        },
    )
    .unwrap();
    client.next_subset().unwrap();
    server.shutdown(); // nobody comes back
    let err = loop {
        // the first call after the kill may still see buffered bytes;
        // keep drawing until the transport failure surfaces
        match client.next_subset() {
            Ok(_) => continue,
            Err(e) => break e,
        }
    };
    let msg = format!("{err:#}");
    assert!(
        msg.contains("giving up") && msg.contains("2 reconnect"),
        "give-up error must name the exhausted budget: {msg}"
    );
}

#[test]
fn a_restarted_server_with_a_different_seed_is_refused() {
    let meta = meta();
    let server = SubsetServer::bind("127.0.0.1:0", meta.clone(), None, SEED).unwrap();
    let addr = server.addr().to_string();
    let mut client = ServeClient::connect_with(
        &addr,
        "trainer-suspicious",
        ClientOptions {
            retry: RetryPolicy { max_reconnects: 2, backoff_ms: 5 },
            ..Default::default()
        },
    )
    .unwrap();
    client.next_subset().unwrap();
    server.shutdown();
    // same address, same artifact — but a different stream seed: resuming
    // would splice two unrelated streams together
    let imposter = SubsetServer::bind(&addr, meta, None, SEED + 1).unwrap();
    let err = loop {
        match client.next_subset() {
            Ok(_) => continue,
            Err(e) => break e,
        }
    };
    let msg = format!("{err:#}");
    assert!(
        msg.contains("refusing to resume") || msg.contains("seed"),
        "seed mismatch must be refused: {msg}"
    );
    imposter.shutdown();
}

#[test]
fn trace_ids_stay_fresh_and_echoed_across_a_restart() {
    // the causal-tracing contract under reconnect: every logical request
    // gets its own wire trace id, the server echoes it on the control
    // reply, and a transparent reconnect-and-replay neither reuses an old
    // id nor loses the capability (it is re-learned from the new HELLO)
    let meta = meta();
    let server = SubsetServer::bind("127.0.0.1:0", meta.clone(), None, SEED).unwrap();
    let addr = server.addr().to_string();
    let mut client = ServeClient::connect_with(
        &addr,
        "trainer-traced",
        retrying_options(WireMode::Json),
    )
    .unwrap();
    assert!(client.trace_capable(), "HELLO must ack the trace capability");
    assert!(client.last_trace().is_none(), "no stamped request yet");

    let mut seen = Vec::new();
    client.next_subset().unwrap();
    let (first, echoed) = client.last_trace().unwrap();
    assert!(first != 0 && echoed, "JSON control replies echo the trace id");
    seen.push(first);

    server.shutdown();
    let server2 = SubsetServer::bind(&addr, meta, None, SEED).unwrap();

    for _ in 0..2 {
        client.next_subset().unwrap();
        let (trace, echoed) = client.last_trace().unwrap();
        assert!(echoed, "echo must survive the reconnect-and-replay");
        assert!(
            !seen.contains(&trace),
            "trace ids are per logical request, never replayed: {trace:#x}"
        );
        seen.push(trace);
    }
    assert!(client.trace_capable(), "capability re-learned after restart");
    server2.shutdown();
}

#[test]
fn reconnect_replays_wre_draw_sizes_exactly() {
    // a client whose pre-kill history mixes WRE draw sizes: the replay
    // must re-issue the same k sequence or the post-restart stream drifts
    let meta = meta();
    let server = SubsetServer::bind("127.0.0.1:0", meta.clone(), None, SEED).unwrap();
    let addr = server.addr().to_string();
    let mut client = ServeClient::connect_with(
        &addr,
        "trainer-mixed-k",
        retrying_options(WireMode::Frame),
    )
    .unwrap();
    let ks = [8usize, 32, 16];
    let mut got: Vec<Vec<usize>> = ks.iter().map(|&k| client.sample_wre(k).unwrap()).collect();
    server.shutdown();
    let server2 = SubsetServer::bind(&addr, meta.clone(), None, SEED).unwrap();
    got.push(client.sample_wre(WRE_K).unwrap());

    let wre_inline = WreStrategy::new("inline", meta.wre_classes.clone());
    let mut rng = client_stream_rng(SEED, &meta, "trainer-mixed-k");
    let expect: Vec<Vec<usize>> = ks
        .iter()
        .chain(std::iter::once(&WRE_K))
        .map(|&k| wre_inline.sample_k(k, &mut rng))
        .collect();
    assert_eq!(got, expect, "mixed-k WRE stream diverged across restart");
    server2.shutdown();
}
