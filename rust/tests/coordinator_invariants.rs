//! Property tests on coordinator invariants: allocation, pre-processing
//! outputs (subset structure, WRE distributions), batching/padding, and
//! the kernel-free path's structural agreement with the kernel path.
//! (PJRT-dependent tests skip when `artifacts/` is absent.)

use milo::coordinator::{PreprocessOptions, Preprocessor};
use milo::data::{DatasetId, Split};
use milo::kernel::SimilarityBackend;
use milo::runtime::Runtime;
use milo::selection::proportional_allocation;
use milo::testkit::check_cases;
use milo::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    milo::testkit::artifacts_or_skip()
}

// ---------------------------------------------------------------------------
// proportional allocation
// ---------------------------------------------------------------------------

#[test]
fn prop_allocation_is_exact_and_capacity_bounded() {
    check_cases(400, 40, |seed| {
        let mut rng = Rng::new(seed);
        let c = 1 + rng.below(40);
        let sizes: Vec<usize> = (0..c).map(|_| rng.below(300)).collect();
        let n: usize = sizes.iter().sum();
        let k = rng.below(n + 2);
        let alloc = proportional_allocation(&sizes, k);
        assert_eq!(alloc.len(), c);
        let total: usize = alloc.iter().sum();
        assert_eq!(total, k.min(n), "total {total} != k {k} (n={n})");
        for (a, s) in alloc.iter().zip(&sizes) {
            assert!(a <= s, "alloc {a} exceeds class size {s}");
        }
    });
}

#[test]
fn prop_allocation_is_roughly_proportional() {
    check_cases(401, 20, |seed| {
        let mut rng = Rng::new(seed);
        let c = 2 + rng.below(10);
        let sizes: Vec<usize> = (0..c).map(|_| 50 + rng.below(200)).collect();
        let n: usize = sizes.iter().sum();
        let k = n / 4;
        let alloc = proportional_allocation(&sizes, k);
        for (a, s) in alloc.iter().zip(&sizes) {
            let exact = k as f64 * *s as f64 / n as f64;
            assert!(
                (*a as f64 - exact).abs() <= 1.0 + 1e-9,
                "alloc {a} vs exact {exact:.2}"
            );
        }
    });
}

#[test]
fn allocation_degenerate_cases() {
    assert_eq!(proportional_allocation(&[], 5), Vec::<usize>::new());
    assert_eq!(proportional_allocation(&[0, 0], 5), vec![0, 0]);
    assert_eq!(proportional_allocation(&[10], 0), vec![0]);
    assert_eq!(proportional_allocation(&[3, 3], 100), vec![3, 3]); // k > n clamps
    // single-element classes all get a slot when k = n
    assert_eq!(proportional_allocation(&[1, 1, 1], 3), vec![1, 1, 1]);
}

// ---------------------------------------------------------------------------
// pre-processing output invariants
// ---------------------------------------------------------------------------

fn preprocessor<'a>(rt: &'a Runtime, fraction: f64, seed: u64) -> Preprocessor<'a> {
    Preprocessor::with_options(
        rt,
        PreprocessOptions {
            fraction,
            backend: SimilarityBackend::Native,
            seed,
            ..Default::default()
        },
    )
}

#[test]
fn preprocessing_outputs_are_structurally_sound() {
    let Some(rt) = runtime() else { return };
    for &(ds_id, fraction) in &[
        (DatasetId::Trec6Like, 0.05),
        (DatasetId::Cifar10Like, 0.1),
        (DatasetId::DermaLike, 0.1),
    ] {
        let ds = ds_id.generate(3);
        let k = (fraction * ds.n_train() as f64).round() as usize;
        let meta = preprocessor(&rt, fraction, 3).run(&ds).unwrap();

        // SGE subsets: right size, sorted, unique, in-range
        assert!(!meta.sge_subsets.is_empty());
        for s in &meta.sge_subsets {
            assert_eq!(s.len(), k, "{}: subset size", ds.name());
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < ds.n_train()));
        }

        // WRE: one distribution per class, each a simplex over its class
        let parts = ds.class_partition();
        assert_eq!(meta.wre_classes.len(), ds.classes());
        for (c, cp) in meta.wre_classes.iter().enumerate() {
            assert_eq!(cp.indices.len(), parts[c].len(), "class {c}");
            let sum: f64 = cp.probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "class {c} probs sum {sum}");
            assert!(cp.probs.iter().all(|&p| p > 0.0), "Taylor-softmax is positive");
            for &i in &cp.indices {
                assert_eq!(ds.train_y[i] as usize, c);
            }
        }

        // fixed subset: same structural rules
        assert_eq!(meta.fixed_dm.len(), k);
        assert!(meta.fixed_dm.windows(2).all(|w| w[0] < w[1]));
    }
}

#[test]
fn kernel_free_path_matches_kernel_path_structure() {
    let Some(rt) = runtime() else { return };
    let ds = DatasetId::Trec6Like.generate(5);
    let fraction = 0.1;
    let pre = preprocessor(&rt, fraction, 5);
    let a = pre.run(&ds).unwrap();
    let b = pre.run_featurebased(&ds).unwrap();
    assert_eq!(a.sge_subsets.len(), b.sge_subsets.len());
    for (x, y) in a.sge_subsets.iter().zip(&b.sge_subsets) {
        assert_eq!(x.len(), y.len());
    }
    assert_eq!(a.wre_classes.len(), b.wre_classes.len());
    for (x, y) in a.wre_classes.iter().zip(&b.wre_classes) {
        assert_eq!(x.indices, y.indices);
        let sum: f64 = y.probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }
    assert_eq!(a.fixed_dm.len(), b.fixed_dm.len());
}

#[test]
fn per_class_budgets_respected_in_sge_subsets() {
    let Some(rt) = runtime() else { return };
    let ds = DatasetId::Cifar10Like.generate(7);
    let fraction = 0.1;
    let k = (fraction * ds.n_train() as f64).round() as usize;
    let meta = preprocessor(&rt, fraction, 7).run(&ds).unwrap();
    let parts = ds.class_partition();
    let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
    let alloc = proportional_allocation(&sizes, k);
    for s in &meta.sge_subsets {
        let mut by_class = vec![0usize; ds.classes()];
        for &i in s {
            by_class[ds.train_y[i] as usize] += 1;
        }
        assert_eq!(by_class, alloc, "per-class composition drifted");
    }
}

#[test]
fn encoder_variants_change_geometry_but_not_contract() {
    let Some(rt) = runtime() else { return };
    let ds = DatasetId::Trec6Like.generate(1);
    let base = preprocessor(&rt, 0.05, 1).encode(&ds, Split::Train).unwrap();
    for variant in ["mean32", "alt32", "wide64", "narrow16"] {
        let pre = Preprocessor::with_options(
            &rt,
            PreprocessOptions {
                fraction: 0.05,
                backend: SimilarityBackend::Native,
                encoder_variant: Some(variant.into()),
                ..Default::default()
            },
        );
        let z = pre.encode(&ds, Split::Train).unwrap();
        assert_eq!(z.rows, ds.n_train(), "{variant}: row count");
        // rows are unit-normalized for every variant
        for i in (0..z.rows).step_by(97) {
            let n2: f32 = z.row(i).iter().map(|v| v * v).sum();
            assert!((n2 - 1.0).abs() < 1e-3, "{variant} row {i}: norm² {n2}");
        }
        // and the geometry actually differs from the default encoder
        if z.cols == base.cols {
            let same = (0..z.rows.min(50))
                .all(|i| z.row(i).iter().zip(base.row(i)).all(|(a, b)| (a - b).abs() < 1e-6));
            assert!(!same, "{variant} is identical to the default encoder");
        }
    }
}

#[test]
fn unknown_encoder_variant_is_an_error() {
    let Some(rt) = runtime() else { return };
    let ds = DatasetId::Trec6Like.generate(1);
    let pre = Preprocessor::with_options(
        &rt,
        PreprocessOptions {
            encoder_variant: Some("nope99".into()),
            ..Default::default()
        },
    );
    assert!(pre.encode(&ds, Split::Train).is_err());
}

// ---------------------------------------------------------------------------
// trainer batching / padding
// ---------------------------------------------------------------------------

#[test]
fn training_handles_subsets_smaller_than_one_batch() {
    // k < BATCH forces a single padded batch; masked padding must not
    // poison the loss/metrics
    let Some(rt) = runtime() else { return };
    use milo::selection::FixedStrategy;
    use milo::train::{TrainConfig, Trainer};
    let ds = DatasetId::Trec6Like.generate(2);
    let subset: Vec<usize> = (0..30).collect(); // 30 < 128 batch
    let cfg = TrainConfig {
        epochs: 3,
        fraction: 30.0 / ds.n_train() as f64,
        eval_every: 0,
        seed: 2,
        ..TrainConfig::recipe_for(&ds, 3)
    };
    let mut strat = FixedStrategy::new("tiny", subset);
    let out = Trainer::new(&rt, &ds, cfg).unwrap().run(&mut strat).unwrap();
    assert!(out.test_accuracy.is_finite());
    assert!(out.test_accuracy >= 0.0 && out.test_accuracy <= 1.0);
    for p in &out.trace {
        assert!(p.val_loss.is_finite(), "loss went non-finite");
    }
}

#[test]
fn training_is_bit_deterministic_per_seed() {
    let Some(rt) = runtime() else { return };
    use milo::selection::RandomStrategy;
    use milo::train::{TrainConfig, Trainer};
    let ds = DatasetId::Trec6Like.generate(4);
    let run = |seed: u64| {
        let cfg = TrainConfig {
            epochs: 4,
            fraction: 0.1,
            eval_every: 0,
            seed,
            ..TrainConfig::recipe_for(&ds, 4)
        };
        Trainer::new(&rt, &ds, cfg)
            .unwrap()
            .run(&mut RandomStrategy::new())
            .unwrap()
            .test_accuracy
    };
    // param seeds are pre-baked for 1..=5 (aot.py PARAM_SEEDS)
    assert_eq!(run(3), run(3));
}
