//! Causal-tracing acceptance: a frame-wire request carrying wire trace
//! context must come back out of the trace sink as ONE multi-level span
//! tree — client request span → server dispatch span → `store.resolve` →
//! the kernel-build span underneath it — reconstructable by the same
//! parser `milo trace` uses.
//!
//! The sink under test is the always-on flight recorder's dump
//! ([`milo::obs::flight::dump_jsonl`]), which emits the identical
//! schema-v2 JSON lines a `MILO_TRACE` file holds — so the assertions
//! run without mutating process environment. The server's deferred-entry
//! path supplies the depth: the first `HELLO` against a cold entry runs
//! its resolver (a [`MetaStore::get_or_build`] around a real native
//! kernel build) inside the dispatch span, so the whole chain shares the
//! client's trace id.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use milo::data::DatasetId;
use milo::kernel::sparse::sparse_native_scheduled;
use milo::kernel::{KernelSchedule, SimMetric};
use milo::serve::{frame, DeferredEntry, Frame, FrameDecoder, ServeOptions, SubsetServer};
use milo::store::{MetaKey, MetaStore};
use milo::testkit::{random_embeddings, synthetic_metadata};

/// A deferred single-entry server whose resolver goes through the store
/// and a real (serial-scheduled, so same-thread) native kernel build.
fn deferred_server(tag: &str) -> SubsetServer {
    let dir = std::env::temp_dir()
        .join(format!("milo_trace_tree_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = MetaStore::open(&dir).unwrap();
    let ds = DatasetId::Trec6Like.generate(5);
    let meta = synthetic_metadata(&ds, 0.1);
    let key = MetaKey::from_options(
        &meta.dataset,
        &milo::coordinator::PreprocessOptions::default(),
    );
    let entries = vec![DeferredEntry {
        dataset: meta.dataset.clone(),
        fraction: meta.fraction,
        resolve: Box::new(move || {
            let built = store.get_or_build(&key, || {
                // a real kernel build under `store.resolve`: the serial
                // schedule keeps `kernel.execute` on this thread, so the
                // span lands inside the ambient dispatch context
                let z = random_embeddings(24, 6, 11);
                sparse_native_scheduled(
                    &z,
                    SimMetric::Cosine,
                    4,
                    &KernelSchedule::serial(),
                )?;
                Ok(meta.clone())
            })?;
            Ok((*built).clone())
        }),
    }];
    SubsetServer::bind_deferred("127.0.0.1:0", entries, None, 7, ServeOptions::default())
        .unwrap()
}

#[test]
fn frame_wire_request_reconstructs_a_multi_level_span_tree() {
    let server = deferred_server("tree");
    let addr = server.addr().to_string();

    // --- request 1: a stamped frame-negotiating HELLO. Its dispatch
    // resolves the cold entry, so the whole build chain joins this trace.
    let hello_span = milo::obs::Span::enter("serve.client.hello");
    let trace = hello_span.trace_id();
    assert_ne!(trace, 0, "observability is on by default");
    let trace_hex = milo::obs::id_hex(trace);
    let span_hex = milo::obs::id_hex(hello_span.span_id());

    let sock = TcpStream::connect(&addr).unwrap();
    sock.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut writer = sock;
    writer
        .write_all(
            format!(
                "{{\"cmd\":\"HELLO\",\"client\":\"tracer\",\"wire\":\"frame\",\
                 \"trace\":\"{trace_hex}\",\"span\":\"{span_hex}\"}}\n"
            )
            .as_bytes(),
        )
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "HELLO failed: {line:?}");
    assert!(
        line.contains("\"trace\":true"),
        "HELLO reply must ack the trace capability: {line:?}"
    );
    drop(hello_span); // finish after the round trip, like a real client

    // --- request 2: a stamped frame-wire NEXT_SUBSET (binary reply),
    // with a bare wire id as both trace and request span
    let draw_trace = milo::obs::next_id();
    let draw_hex = milo::obs::id_hex(draw_trace);
    let mut buf = Vec::new();
    frame::write_frame_on(
        &mut buf,
        0,
        frame::KIND_JSON,
        format!(
            "{{\"cmd\":\"NEXT_SUBSET\",\"trace\":\"{draw_hex}\",\
             \"span\":\"{draw_hex}\"}}"
        )
        .as_bytes(),
    );
    writer.write_all(&buf).unwrap();
    let mut decoder = FrameDecoder::new();
    let reply = loop {
        if let Some(f) = decoder.next().unwrap() {
            break f;
        }
        let mut chunk = [0u8; 4096];
        let n = std::io::Read::read(&mut reader, &mut chunk).unwrap();
        assert!(n > 0, "server closed before replying");
        decoder.push(&chunk[..n]);
    };
    assert!(
        matches!(reply, Frame::Subset { .. }),
        "frame-wire NEXT_SUBSET reply must be a SUBSET frame, got {}",
        reply.kind_name()
    );
    drop(writer);

    // --- reconstruct the HELLO's tree from the sink text
    let dump = milo::obs::flight::dump_jsonl();
    let events = milo::obs::traceview::parse_lines(&dump);
    let find = |name: &str| {
        events
            .iter()
            .find(|e| e.trace == trace && e.name == name)
            .unwrap_or_else(|| panic!("span {name} missing for trace {trace_hex}"))
    };
    let client = find("serve.client.hello");
    let dispatch = find("serve.hello");
    let resolve = find("store.resolve");
    let kernel = find("kernel.execute");
    assert_eq!(client.parent, 0, "the client request span roots the trace");
    assert_eq!(dispatch.parent, client.span, "dispatch hangs off the request");
    assert_eq!(resolve.parent, dispatch.span, "resolution inside dispatch");
    assert_eq!(kernel.parent, resolve.span, "kernel build inside the resolve");

    // the second request's dispatch span carries the wire ids too
    let draw = events
        .iter()
        .find(|e| e.trace == draw_trace && e.name == "serve.next_subset")
        .expect("framed NEXT_SUBSET dispatch span joins the wire trace");
    assert_eq!(draw.parent, draw_trace, "parented on the stamped wire span");

    // and the renderer `milo trace` uses shows the chain nested in order
    let report = milo::obs::traceview::report(&dump, usize::MAX);
    let pos = |name: &str| {
        let tree = &report[report.find(&format!("trace {trace_hex}")).unwrap()..];
        tree.find(name).unwrap_or_else(|| panic!("{name} not rendered"))
    };
    assert!(pos("serve.client.hello") < pos("serve.hello"));
    assert!(pos("serve.hello") < pos("store.resolve"));
    assert!(pos("store.resolve") < pos("kernel.execute"));

    server.shutdown();
}

/// The `FLIGHT` control command: any session can pull the recorder's
/// counters and tail-samples over the serve protocol itself.
#[test]
fn flight_command_reports_recorder_stats_over_the_wire() {
    let server = deferred_server("flight");
    let addr = server.addr().to_string();

    let sock = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut writer = sock;
    writer
        .write_all(b"{\"cmd\":\"HELLO\",\"client\":\"flight-probe\"}\n")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "HELLO failed: {line:?}");

    writer.write_all(b"{\"cmd\":\"FLIGHT\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = milo::util::json::Json::parse(line.trim()).unwrap();
    assert_eq!(v.opt("ok").and_then(|o| o.as_bool().ok()), Some(true));
    let flight = v.opt("flight").expect("FLIGHT reply carries recorder stats");
    assert_eq!(
        flight.opt("enabled").and_then(|e| e.as_bool().ok()),
        Some(true),
        "the recorder is always on by default"
    );
    assert!(
        flight.opt("recorded").and_then(|r| r.as_f64().ok()).unwrap_or(0.0)
            >= 1.0,
        "the HELLO itself must already be in the ring: {line:?}"
    );
    assert!(v.opt("samples").is_some(), "FLIGHT reply lists tail-samples");
    server.shutdown();
}
