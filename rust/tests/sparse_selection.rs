//! Property suite for the sparse top-`knn` kernel path (testkit-driven
//! seed sweeps):
//!
//! * `knn ≥ n_c` selections are **bit-identical** to the dense path for
//!   every `SetFunctionKind` × greedy mode × metric;
//! * sparse-kernel structural invariants (row-sorted columns, symmetric
//!   top-k union, self-loops never lost);
//! * sparse gains equal dense gains over the zero-densified kernel
//!   (the "implicit zeros" semantics) for `knn < n_c`;
//! * degenerate classes (`n_c ≤ knn`, `n_c = 1`) survive the full
//!   per-class pipeline;
//! * the dense and sparse-complete pipelines produce byte-identical
//!   store artifacts, while `knn` addresses separately in the `MetaKey`.

use milo::coordinator::{
    fixed_subset_from_kernels, sge_subsets_from_kernels,
    wre_distribution_from_kernels, Metadata, PreprocessOptions,
};
use milo::kernel::{
    build_class_kernels, build_sparse_kernel, native_similarity, SimMetric,
    SimilarityBackend, SparseKernel,
};
use milo::store::{binfmt, MetaKey};
use milo::submod::{
    greedy_maximize, sample_importance, GreedyMode, SetFunctionKind,
};
use milo::tensor::Matrix;
use milo::testkit::{check_cases, random_embeddings, random_kernel};
use milo::util::rng::Rng;

const KINDS: [SetFunctionKind; 4] = [
    SetFunctionKind::FacilityLocation,
    SetFunctionKind::GraphCut { lambda: 0.4 },
    SetFunctionKind::DisparitySum,
    SetFunctionKind::DisparityMin,
];

#[test]
fn prop_complete_sparse_selections_match_dense_bitwise() {
    check_cases(900, 10, |seed| {
        let n = 10 + (seed % 24) as usize;
        let e = 4 + (seed % 5) as usize;
        let z = random_embeddings(n, e, seed);
        for metric in [SimMetric::Cosine, SimMetric::Dot, SimMetric::Rbf { kw: 0.5 }] {
            let dense = native_similarity(&z, metric);
            let sparse =
                build_sparse_kernel(None, &z, metric, SimilarityBackend::Native, n)
                    .unwrap();
            assert!(sparse.is_complete());
            for kind in KINDS {
                let k = (1 + (seed % 7) as usize).min(n);
                for mode in [
                    GreedyMode::Naive,
                    GreedyMode::Lazy,
                    GreedyMode::Stochastic { epsilon: 0.05 },
                ] {
                    let mut rng_d = Rng::new(seed ^ 0xD00D);
                    let mut rng_s = Rng::new(seed ^ 0xD00D);
                    let mut fd = kind.build(&dense);
                    let td =
                        greedy_maximize(fd.as_mut(), k, mode, kind.lazy_safe(), &mut rng_d);
                    let mut fs = kind.build_sparse(&sparse);
                    let ts =
                        greedy_maximize(fs.as_mut(), k, mode, kind.lazy_safe(), &mut rng_s);
                    assert_eq!(
                        td.selected, ts.selected,
                        "{kind:?} {mode:?} {metric:?} seed {seed}: selections diverged"
                    );
                    assert_eq!(
                        td.gains, ts.gains,
                        "{kind:?} {mode:?} {metric:?} seed {seed}: gains diverged"
                    );
                }
                // the WRE importance sweep must agree bitwise too
                let mut fd = kind.build(&dense);
                let gd = sample_importance(fd.as_mut(), kind.lazy_safe());
                let mut fs = kind.build_sparse(&sparse);
                let gs = sample_importance(fs.as_mut(), kind.lazy_safe());
                assert_eq!(gd, gs, "{kind:?} {metric:?} seed {seed}: importances diverged");
            }
        }
    });
}

#[test]
fn prop_sparse_kernel_invariants() {
    check_cases(901, 10, |seed| {
        let n = 12 + (seed % 30) as usize;
        let z = random_embeddings(n, 6, seed);
        for knn in [1usize, 3, 8, n / 2 + 1, n, n + 5] {
            let k = build_sparse_kernel(
                None,
                &z,
                SimMetric::Cosine,
                SimilarityBackend::Native,
                knn,
            )
            .unwrap();
            assert_eq!(k.n(), n);
            let mut nnz = 0;
            for i in 0..n {
                let (cols, vals) = k.row(i);
                nnz += cols.len();
                assert_eq!(cols.len(), vals.len());
                // each row keeps at least its own top-knn (self-loop
                // included) and never exceeds the ground set
                assert!(cols.len() >= knn.min(n), "row {i} lost entries (knn={knn})");
                assert!(cols.len() <= n);
                assert!(
                    cols.windows(2).all(|w| w[0] < w[1]),
                    "row {i} columns not sorted/unique"
                );
                assert!(
                    cols.binary_search(&(i as u32)).is_ok(),
                    "row {i} lost its self-loop (knn={knn})"
                );
                for (&c, &v) in cols.iter().zip(vals) {
                    assert!((-1e-5..=1.0 + 1e-5).contains(&v), "({i},{c}) = {v}");
                    // symmetric union: the mirrored entry exists and
                    // holds the same value
                    assert_eq!(k.at(c as usize, i), v, "asymmetric at ({i},{c})");
                }
            }
            assert_eq!(nnz, k.nnz());
            if knn >= n {
                assert!(k.is_complete());
            }
        }
    });
}

#[test]
fn prop_sparse_gains_match_densified_zeros() {
    // a sparse kernel is semantically a dense kernel with implicit
    // zeros: running the oracles over the explicitly zero-densified
    // matrix must select identically (FL/GC/DS) for knn < n
    check_cases(902, 8, |seed| {
        let n = 14 + (seed % 10) as usize;
        let m = random_kernel(n, seed);
        let knn = 3 + (seed % 4) as usize;
        let sk = SparseKernel::from_dense(&m, knn);
        assert!(!sk.is_complete(), "knn {knn} < n {n} must stay sparse");
        let mut densified = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                densified.set(i, j, sk.at(i, j));
            }
        }
        for kind in [
            SetFunctionKind::FacilityLocation,
            SetFunctionKind::GraphCut { lambda: 0.4 },
            SetFunctionKind::DisparitySum,
        ] {
            let k = (n / 3).max(2);
            let mut rng_a = Rng::new(seed);
            let mut rng_b = Rng::new(seed);
            let mut fa = kind.build(&densified);
            let ta =
                greedy_maximize(fa.as_mut(), k, GreedyMode::Naive, kind.lazy_safe(), &mut rng_a);
            let mut fb = kind.build_sparse(&sk);
            let tb =
                greedy_maximize(fb.as_mut(), k, GreedyMode::Naive, kind.lazy_safe(), &mut rng_b);
            assert_eq!(ta.selected, tb.selected, "{kind:?} seed {seed}");
        }
        // disparity-min: the seed gain's summation order differs
        // (stored-then-absent vs interleaved), so compare to tolerance
        let mut fa = SetFunctionKind::DisparityMin.build(&densified);
        let mut fb = SetFunctionKind::DisparityMin.build_sparse(&sk);
        for j in 0..n {
            assert!(
                (fa.gain(j) - fb.gain(j)).abs() < 1e-4,
                "DM seed gain {j}: {} vs {}",
                fa.gain(j),
                fb.gain(j)
            );
        }
        fa.add(0);
        fb.add(0);
        for j in 0..n {
            assert_eq!(fa.gain(j), fb.gain(j), "DM mindist gain {j} diverged");
        }
        fa.add(n / 2);
        fb.add(n / 2);
        assert_eq!(fa.value(), fb.value());
    });
}

#[test]
fn degenerate_classes_survive_sparse_preprocessing() {
    // n_c = 1, n_c = 2, n_c ≤ knn, n_c > knn in one partition
    let emb = random_embeddings(30, 6, 5);
    let partition: Vec<Vec<usize>> = vec![
        vec![0],
        (1..3).collect(),
        (3..10).collect(),
        (10..30).collect(),
    ];
    for knn in [1usize, 4, 64] {
        let kernels = build_class_kernels(
            None,
            &emb,
            &partition,
            SimMetric::Cosine,
            SimilarityBackend::Native,
            Some(knn),
        )
        .unwrap();
        let mut rng = Rng::new(1);
        let sge = sge_subsets_from_kernels(
            30,
            &kernels,
            SetFunctionKind::GRAPH_CUT_DEFAULT,
            6,
            2,
            0.01,
            &mut rng,
        );
        assert_eq!(sge.len(), 2, "knn={knn}");
        for s in &sge {
            assert_eq!(s.len(), 6, "knn={knn}");
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 30));
        }
        let wre = wre_distribution_from_kernels(&kernels, SetFunctionKind::DisparityMin);
        assert_eq!(wre.len(), 4);
        for (cp, part) in wre.iter().zip(&partition) {
            assert_eq!(&cp.indices, part, "knn={knn}");
            let sum: f64 = cp.probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "knn={knn} probs sum {sum}");
            assert!(cp.probs.iter().all(|&p| p > 0.0));
        }
        let fixed = fixed_subset_from_kernels(30, &kernels, SetFunctionKind::DisparityMin, 6);
        assert_eq!(fixed.len(), 6, "knn={knn}");
        assert!(fixed.windows(2).all(|w| w[0] < w[1]));
    }
}

#[test]
fn complete_sparse_pipeline_is_byte_identical_to_dense() {
    // the acceptance bar: one full preprocessing pass per representation
    // (same seeds), encoded as store artifacts, compared byte-for-byte
    let per = 40usize;
    let classes = 5usize;
    let n = per * classes;
    let emb = random_embeddings(n, 10, 77);
    let partition: Vec<Vec<usize>> = (0..classes)
        .map(|c| (c * per..(c + 1) * per).collect())
        .collect();
    let dense = build_class_kernels(
        None,
        &emb,
        &partition,
        SimMetric::Cosine,
        SimilarityBackend::Native,
        None,
    )
    .unwrap();
    let sparse = build_class_kernels(
        None,
        &emb,
        &partition,
        SimMetric::Cosine,
        SimilarityBackend::Native,
        Some(per), // knn = n_c → complete
    )
    .unwrap();
    let k = n / 10;
    let run = |kernels: &milo::kernel::ClassKernels| -> Metadata {
        let mut rng = Rng::new(3);
        Metadata {
            dataset: "synthetic".into(),
            fraction: 0.1,
            sge_subsets: sge_subsets_from_kernels(
                n,
                kernels,
                SetFunctionKind::GRAPH_CUT_DEFAULT,
                k,
                3,
                0.01,
                &mut rng,
            ),
            wre_classes: wre_distribution_from_kernels(
                kernels,
                SetFunctionKind::DisparityMin,
            ),
            fixed_dm: fixed_subset_from_kernels(
                n,
                kernels,
                SetFunctionKind::DisparityMin,
                k,
            ),
            preprocess_secs: 0.25,
        }
    };
    let a = run(&dense);
    let b = run(&sparse);
    assert_eq!(a.sge_subsets, b.sge_subsets);
    assert_eq!(a.fixed_dm, b.fixed_dm);
    assert_eq!(a.wre_classes, b.wre_classes);
    assert_eq!(
        binfmt::encode(&a),
        binfmt::encode(&b),
        "dense and complete-sparse artifacts must be byte-identical"
    );

    // …while the configurations address separately: knn is part of the
    // MetaKey, so a sparse artifact can never silently shadow a dense one
    let opts = |knn: Option<usize>| PreprocessOptions {
        backend: SimilarityBackend::Native,
        knn,
        ..Default::default()
    };
    let kd = MetaKey::from_options("synthetic", &opts(None));
    let k32 = MetaKey::from_options("synthetic", &opts(Some(32)));
    assert_ne!(kd.fingerprint(), k32.fingerprint());
    assert_ne!(kd, k32);
    // equal configurations still share one address (the amortization)
    let again = MetaKey::from_options("synthetic", &opts(Some(32)));
    assert_eq!(k32, again);
    assert_eq!(k32.fingerprint(), again.fingerprint());
}
