//! Behavioural contracts of the selection strategies (adaptive vs fixed,
//! caching, class balance, exploration-share decay).

use milo::coordinator::{PreprocessOptions, Preprocessor};
use milo::data::DatasetId;
use milo::kernel::SimilarityBackend;
use milo::runtime::Runtime;
use milo::selection::{
    AdaptiveRandomStrategy, ModelProbe, RandomStrategy, SelectCtx, SgeVariantStrategy,
    Strategy,
};
use milo::train::model::MlpModel;
use milo::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    milo::testkit::artifacts_or_skip()
}

struct Fixture {
    rt: Runtime,
    ds: milo::data::Dataset,
}

impl Fixture {
    fn new() -> Option<Fixture> {
        let rt = runtime()?;
        let ds = DatasetId::Trec6Like.generate(9);
        Some(Fixture { rt, ds })
    }

    /// Model-agnostic selection: no probe, no MlpModel.
    fn select(
        &self,
        strat: &mut dyn Strategy,
        rng: &mut Rng,
        epoch: usize,
        k: usize,
    ) -> Vec<usize> {
        let mut ctx = SelectCtx::model_agnostic(&self.ds, epoch, 20, k, rng);
        strat.select(&mut ctx).unwrap()
    }

    /// Model-dependent selection (EL2N, gradient baselines).
    fn select_with_model(
        &self,
        strat: &mut dyn Strategy,
        model: &mut MlpModel,
        rng: &mut Rng,
        epoch: usize,
        k: usize,
    ) -> Vec<usize> {
        let mut ctx = SelectCtx::model_agnostic(&self.ds, epoch, 20, k, rng)
            .with_probe(ModelProbe::new(&self.rt, model));
        strat.select(&mut ctx).unwrap()
    }
}

#[test]
fn random_strategy_caches_first_draw() {
    let Some(fx) = Fixture::new() else { return };
    let mut rng = Rng::new(1);
    let mut s = RandomStrategy::new();
    let a = fx.select(&mut s, &mut rng, 0, 50);
    let b = fx.select(&mut s, &mut rng, 5, 50);
    assert_eq!(a, b, "RANDOM must reuse its first subset");
    assert!(!s.is_adaptive());
}

#[test]
fn adaptive_random_redraws() {
    let Some(fx) = Fixture::new() else { return };
    let mut rng = Rng::new(2);
    let mut s = AdaptiveRandomStrategy;
    let a = fx.select(&mut s, &mut rng, 0, 50);
    let b = fx.select(&mut s, &mut rng, 1, 50);
    assert_ne!(a, b, "ADAPTIVE-RANDOM must redraw");
    assert!(s.is_adaptive());
}

#[test]
fn sge_variant_greedy_share_decays() {
    let Some(fx) = Fixture::new() else { return };
    let pre = Preprocessor::with_options(
        &fx.rt,
        PreprocessOptions {
            fraction: 0.1,
            backend: SimilarityBackend::Native,
            ..Default::default()
        },
    );
    let meta = pre.run(&fx.ds).unwrap();
    let sge_pool: std::collections::HashSet<usize> =
        meta.sge_subsets.iter().flatten().cloned().collect();
    let mut s = SgeVariantStrategy::new(meta.sge_subsets.clone());
    let mut rng = Rng::new(3);
    let k = 120;
    // early epoch: almost all picks from the SGE pool; late epoch: few
    let early = fx.select(&mut s, &mut rng, 0, k);
    let late = fx.select(&mut s, &mut rng, 19, k);
    let overlap = |sel: &[usize]| sel.iter().filter(|i| sge_pool.contains(i)).count();
    let (e, l) = (overlap(&early), overlap(&late));
    assert!(
        e > l + k / 4,
        "greedy share must decay: early {e}, late {l} of {k}"
    );
    assert_eq!(early.len(), k);
    assert_eq!(late.len(), k);
}

#[test]
fn milo_fixed_subset_is_disparity_min_selection() {
    let Some(fx) = Fixture::new() else { return };
    let pre = Preprocessor::with_options(
        &fx.rt,
        PreprocessOptions {
            fraction: 0.1,
            backend: SimilarityBackend::Native,
            ..Default::default()
        },
    );
    let meta = pre.run(&fx.ds).unwrap();
    let mut s = meta.milo_fixed_strategy();
    assert_eq!(s.name(), "milo_fixed");
    let mut rng = Rng::new(4);
    let sel = fx.select(&mut s, &mut rng, 0, 240);
    assert_eq!(sel, meta.fixed_dm);
}

#[test]
fn wre_respects_class_balance_with_imbalanced_partition() {
    // Craft an imbalanced ClassProbs set and verify proportional sampling.
    use milo::selection::milo::ClassProbs;
    use milo::selection::WreStrategy;
    let classes = vec![
        ClassProbs { indices: (0..300).collect(), probs: vec![1.0; 300] },
        ClassProbs { indices: (300..400).collect(), probs: vec![1.0; 100] },
        ClassProbs { indices: (400..420).collect(), probs: vec![1.0; 20] },
    ];
    let wre = WreStrategy::new("t", classes);
    let mut rng = Rng::new(5);
    let sel = wre.sample_k(42, &mut rng);
    assert_eq!(sel.len(), 42);
    let c0 = sel.iter().filter(|&&i| i < 300).count();
    let c1 = sel.iter().filter(|&&i| (300..400).contains(&i)).count();
    let c2 = sel.iter().filter(|&&i| i >= 400).count();
    assert_eq!(c0, 30);
    assert_eq!(c1, 10);
    assert_eq!(c2, 2);
}

#[test]
fn el2n_prune_is_cached_across_calls() {
    let Some(fx) = Fixture::new() else { return };
    let mut s = milo::selection::El2nPruneStrategy::new(1);
    let mut model = MlpModel::load(&fx.rt, "trec6", 128, 1).unwrap();
    let mut rng = Rng::new(6);
    let a = fx.select_with_model(&mut s, &mut model, &mut rng, 0, 60);
    let b = fx.select_with_model(&mut s, &mut model, &mut rng, 3, 60);
    assert_eq!(a, b, "pruning must be computed once");
    assert!(!s.is_adaptive());
}
