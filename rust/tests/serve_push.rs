//! Integration tests for epoch-versioned push serving: a continual
//! producer publishes new selection epochs into a running `SubsetServer`,
//! and subscribed frame-wire clients receive `EPOCH_ADVANCE` +
//! `SUBSET_DELTA` bursts. Asserts the subsystem's contracts:
//!
//!   (a) every published epoch is observed by a subscribed follower
//!       exactly once, in order, with byte-exact subset contents;
//!   (b) the per-client subset/WRE streams at an epoch are deterministic —
//!       a fresh connection with the same id resumes the identical
//!       stream, and epoch streams differ from the epoch-0 base stream;
//!   (c) subscriber slots are reclaimed on GOODBYE and on abrupt
//!       disconnect, so later broadcasts never write to dead slots;
//!   (d) non-subscribed clients simply observe the new head through the
//!       ordinary request path (`GET_META` after the swap).
//!
//! The producer here is a real [`milo::continual::ContinualSelector`], so
//! the epochs carry genuinely re-selected (incrementally maintained)
//! MILO metadata rather than hand-mutated fixtures.

use std::sync::Arc;

use milo::continual::{ContinualOptions, ContinualSelector};
use milo::coordinator::Metadata;
use milo::selection::WreStrategy;
use milo::serve::{
    client_start_cursor, client_stream_rng_at, ClientOptions, ServeClient,
    SubsetServer, WireMode,
};
use milo::testkit::random_embeddings;

const SEED: u64 = 7;
const DATASET: &str = "pushed";
const CLASSES: usize = 3;
const DIM: usize = 6;

/// A continual producer fed `waves` arrival batches, advancing one epoch
/// per wave; returns the selector plus every epoch's metadata.
fn produce(waves: usize) -> (ContinualSelector, Vec<Arc<Metadata>>) {
    let mut opts = ContinualOptions::new(DATASET);
    opts.seed = SEED;
    opts.knn = Some(4);
    let mut sel = ContinualSelector::new(opts);
    let mut metas = Vec::new();
    let z = random_embeddings(30 * waves, DIM, 11);
    for w in 0..waves {
        for i in 30 * w..30 * (w + 1) {
            sel.arrive(i % CLASSES, z.row(i)).unwrap();
        }
        let (meta, stats) = sel.advance_epoch().unwrap();
        assert_eq!(stats.epoch, w as u64 + 1);
        metas.push(Arc::new(meta));
    }
    (sel, metas)
}

fn subscriber(addr: &str, id: &str) -> ServeClient {
    ServeClient::connect_with(
        addr,
        id,
        ClientOptions {
            wire: WireMode::Frame,
            dataset: Some(DATASET.to_string()),
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn every_published_epoch_is_pushed_exactly_once_in_order() {
    let (mut sel, mut metas) = produce(1);
    let server =
        SubsetServer::bind("127.0.0.1:0", metas[0].clone(), None, SEED).unwrap();
    let addr = server.addr().to_string();

    let mut follower = subscriber(&addr, "follower");
    let (epoch0, n_subsets) = follower.subscribe().unwrap();
    assert_eq!(epoch0, 0, "bind-time state is epoch 0");
    assert_eq!(n_subsets, metas[0].sge_subsets.len());

    // publish three more epochs before the follower polls: the bursts
    // queue on the socket and must come out once each, in order
    let z = random_embeddings(90, DIM, 13);
    for w in 0..3usize {
        for i in 30 * w..30 * (w + 1) {
            sel.arrive(i % CLASSES, z.row(i)).unwrap();
        }
        let (meta, stats) = sel.advance_epoch().unwrap();
        let meta = Arc::new(meta);
        server.publish(DATASET, stats.epoch, meta.clone()).unwrap();
        metas.push(meta);
    }

    for (i, want) in metas[1..].iter().enumerate() {
        let update = follower
            .poll_push(5_000)
            .unwrap()
            .expect("published epoch must arrive");
        assert_eq!(update.epoch, i as u64 + 2, "epochs arrive in publish order");
        assert_eq!(update.sge_subsets, want.sge_subsets, "epoch {}", update.epoch);
        assert_eq!(update.fixed_dm, want.fixed_dm, "epoch {}", update.epoch);
        assert_eq!(follower.server_epoch(), update.epoch);
    }
    // exactly once: nothing further arrives
    assert!(follower.poll_push(100).unwrap().is_none());
    assert_eq!(server.epoch_of(DATASET), Some(4));

    let stats = server.stats();
    // one EPOCH_ADVANCE + n SGE deltas + one fixed delta, per publish
    let per_burst = 2 + metas[0].sge_subsets.len() as u64;
    assert_eq!(stats.push_frames, 3 * per_burst);
    assert_eq!(stats.subscribers, 1);
    server.shutdown();
}

#[test]
fn epoch_streams_are_deterministic_and_distinct_from_the_base_stream() {
    let (_, metas) = produce(2);
    let server =
        SubsetServer::bind("127.0.0.1:0", metas[0].clone(), None, SEED).unwrap();
    let addr = server.addr().to_string();

    let draw = |id: &str| -> (Vec<(usize, Vec<usize>)>, Vec<Vec<usize>>) {
        let mut c = subscriber(&addr, id);
        let sge = (0..5).map(|_| c.next_subset().unwrap()).collect();
        let wre = (0..3).map(|_| c.sample_wre(8).unwrap()).collect();
        (sge, wre)
    };
    let base = draw("trainer");

    server.publish(DATASET, 2, metas[1].clone()).unwrap();
    let at2 = draw("trainer");
    assert_eq!(at2, draw("trainer"), "reconnect at epoch 2 must resume the stream");
    assert_ne!(base.1, at2.1, "epoch 2 WRE stream must be re-derived, not the base");

    // the served epoch stream is exactly the documented inline recipe
    let meta = &metas[1];
    let start = client_start_cursor(meta, "trainer");
    let n = meta.sge_subsets.len();
    for (i, (index, subset)) in at2.0.iter().enumerate() {
        assert_eq!(*index, (start + i) % n);
        assert_eq!(subset, &meta.sge_subsets[*index]);
    }
    let inline = WreStrategy::new("inline", meta.wre_classes.clone());
    let mut rng = client_stream_rng_at(SEED, meta, "trainer", 2);
    for w in &at2.1 {
        assert_eq!(w, &inline.sample_k(8, &mut rng));
    }

    // (d) an ordinary (never-subscribed) client sees the head via GET_META
    let mut plain = ServeClient::connect_with(
        &addr,
        "plain",
        ClientOptions { dataset: Some(DATASET.to_string()), ..Default::default() },
    )
    .unwrap();
    assert_eq!(
        milo::store::binfmt::encode(&plain.get_meta().unwrap()),
        milo::store::binfmt::encode(meta),
    );
    server.shutdown();
}

#[test]
fn follow_iterator_yields_each_epoch_then_ends_on_quiet_timeout() {
    let (_, metas) = produce(3);
    let server =
        SubsetServer::bind("127.0.0.1:0", metas[0].clone(), None, SEED).unwrap();
    let addr = server.addr().to_string();

    let mut follower = subscriber(&addr, "iter");
    follower.subscribe().unwrap();
    server.publish(DATASET, 2, metas[1].clone()).unwrap();
    server.publish(DATASET, 3, metas[2].clone()).unwrap();

    let seen: Vec<u64> = follower
        .follow(300)
        .map(|u| u.unwrap().epoch)
        .collect();
    assert_eq!(seen, vec![2, 3]);
    server.shutdown();
}

#[test]
fn subscriber_slots_are_reclaimed_on_goodbye_and_abrupt_disconnect() {
    let (_, metas) = produce(2);
    let server =
        SubsetServer::bind("127.0.0.1:0", metas[0].clone(), None, SEED).unwrap();
    let addr = server.addr().to_string();

    // polite: GOODBYE while subscribed must leave the subscriber list
    let mut polite = subscriber(&addr, "polite");
    polite.subscribe().unwrap();
    wait_until(|| server.stats().subscribers == 1, "subscribe registered");
    polite.goodbye().unwrap();
    drop(polite);
    wait_until(|| server.stats().subscribers == 0, "goodbye unsubscribes");

    // abrupt: a bare FIN mid-subscription must be swept too
    {
        let mut rude = subscriber(&addr, "rude");
        rude.subscribe().unwrap();
        wait_until(|| server.stats().subscribers == 1, "second subscribe");
        rude.abandon(); // bare FIN — no GOODBYE, not even on Drop
    }
    wait_until(|| server.stats().subscribers == 0, "EOF sweep unsubscribes");

    // a broadcast after the churn reaches only live subscribers (and
    // must not touch the reclaimed slots)
    let mut alive = subscriber(&addr, "alive");
    alive.subscribe().unwrap();
    server.publish(DATASET, 2, metas[1].clone()).unwrap();
    let update = alive.poll_push(5_000).unwrap().expect("live subscriber gets the push");
    assert_eq!(update.epoch, 2);
    let stats = server.stats();
    assert_eq!(stats.subscribers, 1);
    assert_eq!(stats.push_frames, 2 + metas[1].sge_subsets.len() as u64);
    server.shutdown();
}

#[test]
fn shutdown_drains_every_gauge_for_still_open_subscribers() {
    let (_, metas) = produce(1);
    let server =
        SubsetServer::bind("127.0.0.1:0", metas[0].clone(), None, SEED).unwrap();
    let addr = server.addr().to_string();

    // three live subscribers (plus their open slots and buffer capacity)
    // at the moment the loop exits — none say GOODBYE first
    let mut followers: Vec<ServeClient> =
        (0..3).map(|i| subscriber(&addr, &format!("open-{i}"))).collect();
    for f in &mut followers {
        f.subscribe().unwrap();
    }
    wait_until(|| server.stats().subscribers == 3, "all three subscribed");
    assert!(server.stats().open_connections >= 3);

    // the exit path must return every gauge contribution the survivors
    // hold — slots, per-stream subscriptions, and buffer capacity — not
    // just the slot count
    let after = server.shutdown();
    assert_eq!(after.open_connections, 0, "open_connections drained at shutdown");
    assert_eq!(after.subscribers, 0, "subscribers gauge drained at shutdown");
    assert_eq!(after.buffer_bytes, 0, "buffer capacity gauge drained at shutdown");
    drop(followers);
}

fn wait_until(cond: impl Fn() -> bool, what: &str) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while !cond() {
        assert!(std::time::Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
}
