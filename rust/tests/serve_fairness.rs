//! Fair-flush regression test (the event-loop stall bugfix): one client
//! pipelining bulk `GET_META` responses through the server must not
//! inflate other clients' small-request latency. The loop writes in
//! bounded per-connection quanta, round-robin across ready connections,
//! so a multi-hundred-KB outbound backlog drains *alongside* `PING`
//! traffic instead of monopolizing the thread until it is flushed.
//!
//! The bulk load is a raw framed socket that writes a batch of
//! `GET_META` requests before reading any response — building a real
//! outbound backlog well past one write quantum — while measured `PING`
//! clients run concurrently. Asserts the pings' p99 stays bounded and
//! that the bulk connection survives (backpressure + quanta, not the
//! wbuf-cap teardown).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use milo::continual::{ContinualOptions, ContinualSelector};
use milo::coordinator::Metadata;
use milo::serve::{frame, ClientOptions, ServeClient, SubsetServer, WireMode};
use milo::testkit::random_embeddings;

const SEED: u64 = 29;
const DATASET: &str = "fairness";
const CLASSES: usize = 3;
const DIM: usize = 6;

/// A meta instance big enough that pipelined `GET_META` responses build
/// a serious outbound backlog.
fn produce_meta(points: usize) -> Arc<Metadata> {
    let mut opts = ContinualOptions::new(DATASET);
    opts.seed = SEED;
    opts.knn = Some(4);
    let mut sel = ContinualSelector::new(opts);
    let z = random_embeddings(points, DIM, 17);
    for i in 0..points {
        sel.arrive(i % CLASSES, z.row(i)).unwrap();
    }
    let (meta, _) = sel.advance_epoch().unwrap();
    Arc::new(meta)
}

/// Read one frame off a raw framed socket; returns its total wire size.
fn read_frame(reader: &mut BufReader<TcpStream>) -> usize {
    let mut header = [0u8; frame::HEADER_LEN];
    reader.read_exact(&mut header).unwrap();
    let (len, _, _) = frame::parse_header(&header).unwrap();
    let mut payload = vec![0u8; len];
    reader.read_exact(&mut payload).unwrap();
    frame::HEADER_LEN + len
}

/// Dial a raw socket and negotiate the frame wire by hand (so the test
/// controls exactly when responses are read — `ServeClient` reads each
/// response before sending the next request, which can never backlog).
fn raw_framed(addr: &str, client: &str) -> (TcpStream, BufReader<TcpStream>) {
    let sock = TcpStream::connect(addr).unwrap();
    sock.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut w = sock.try_clone().unwrap();
    writeln!(w, "{{\"cmd\":\"HELLO\",\"client\":\"{client}\",\"wire\":\"frame\"}}")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "HELLO failed: {line}");
    assert!(line.contains("\"wire\":\"frame\""), "frame mode not confirmed: {line}");
    (sock, reader)
}

#[test]
fn bulk_get_meta_does_not_inflate_ping_latency() {
    let server =
        SubsetServer::bind("127.0.0.1:0", produce_meta(400), None, SEED).unwrap();
    let addr = server.addr().to_string();

    // measured clients, connected and warmed before the bulk load starts
    let mut pingers: Vec<ServeClient> = (0..3)
        .map(|i| {
            ServeClient::connect_with(
                &addr,
                &format!("ping-{i}"),
                ClientOptions { wire: WireMode::Frame, ..Default::default() },
            )
            .unwrap()
        })
        .collect();
    for p in &mut pingers {
        p.ping().unwrap();
    }

    let stop = Arc::new(AtomicBool::new(false));
    let bulk = {
        let addr = addr.clone();
        let stop = stop.clone();
        std::thread::spawn(move || -> u64 {
            let (mut w, mut reader) = raw_framed(&addr, "bulk");
            let req = frame::Frame::Json("{\"cmd\":\"GET_META\"}".to_string()).encode();
            // size one response, then pipeline enough per batch that the
            // server's outbound backlog clearly exceeds one write quantum
            w.write_all(&req).unwrap();
            let one = read_frame(&mut reader);
            let batch = (600 * 1024 / one).clamp(8, 512);
            let mut moved = one as u64;
            while !stop.load(Ordering::Relaxed) {
                for _ in 0..batch {
                    w.write_all(&req).unwrap();
                }
                for _ in 0..batch {
                    moved += read_frame(&mut reader) as u64;
                }
            }
            moved
        })
    };

    // let the first backlog build, then measure pings against it
    std::thread::sleep(Duration::from_millis(100));
    let mut lat: Vec<Duration> = Vec::with_capacity(300);
    for round in 0..100 {
        for p in pingers.iter_mut() {
            let t0 = Instant::now();
            p.ping().unwrap();
            lat.push(t0.elapsed());
        }
        if round % 10 == 9 {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    stop.store(true, Ordering::Relaxed);
    let moved = bulk.join().unwrap();

    lat.sort();
    let p99 = lat[lat.len() * 99 / 100];
    // generous for CI noise; an unfair loop that flushes a full backlog
    // before touching the next connection blows far past this
    assert!(
        p99 < Duration::from_millis(250),
        "PING p99 {p99:?} under bulk GET_META load (moved {moved} bytes)",
    );
    // the backlog was real: several write quanta crossed the wire
    assert!(moved > 2 * 1024 * 1024, "bulk load too small to exercise fairness: {moved}");

    let stats = server.shutdown();
    // fairness + backpressure carried the load — the wbuf cap never fired
    assert_eq!(stats.wbuf_teardowns, 0);
}
