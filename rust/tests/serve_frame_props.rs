//! Property tests for the serve wire-frame codec (`milo::serve::frame`).
//!
//! The event-loop server reads frames from a nonblocking socket, which
//! delivers arbitrary chunk boundaries — so the decoder must reassemble
//! any split of any valid frame stream byte-identically, and must turn
//! every truncation/corruption into a clean error, never a panic and
//! never an allocation driven by a corrupt length prefix.

use milo::coordinator::Metadata;
use milo::selection::milo::ClassProbs;
use milo::serve::frame::{self, Frame, FrameDecoder};
use milo::testkit::check_cases;
use milo::util::rng::Rng;

/// Random structurally valid metadata (ClassProbs invariant upheld).
fn random_metadata(rng: &mut Rng) -> Metadata {
    let n_classes = 1 + rng.below(4);
    let per_class = 1 + rng.below(40);
    let n = n_classes * per_class;
    Metadata {
        dataset: format!("ds{}", rng.below(1000)),
        fraction: rng.range_f64(0.01, 1.0),
        sge_subsets: (0..rng.below(4))
            .map(|_| rng.sample_indices(n, 1 + rng.below(n)))
            .collect(),
        wre_classes: (0..n_classes)
            .map(|c| {
                let indices: Vec<usize> = (c * per_class..(c + 1) * per_class).collect();
                let probs: Vec<f64> =
                    indices.iter().map(|_| rng.range_f64(0.01, 2.0)).collect();
                ClassProbs { indices, probs }
            })
            .collect(),
        fixed_dm: rng.sample_indices(n, 1 + rng.below(n)),
        preprocess_secs: rng.range_f64(0.0, 100.0),
    }
}

/// A random frame of any kind — including the server-initiated push
/// kinds (`EPOCH_ADVANCE`, `SUBSET_DELTA`) and empty payload edge cases.
fn random_frame(rng: &mut Rng) -> Frame {
    match rng.below(6) {
        0 => {
            // JSON payloads including escapes and non-ASCII
            let docs = [
                "{\"cmd\":\"PING\"}",
                "{\"cmd\":\"HELLO\",\"client\":\"tr\\\"ainer-7\",\"wire\":\"frame\"}",
                "{\"ok\":true,\"msg\":\"é😀\"}",
                "{}",
            ];
            Frame::Json(docs[rng.below(docs.len())].to_string())
        }
        1 => {
            let k = rng.below(200);
            let indices: Vec<usize> =
                (0..k).map(|_| rng.below(u32::MAX as usize)).collect();
            let index = if rng.chance(0.2) {
                frame::NO_INDEX
            } else {
                rng.below(1000) as u32
            };
            Frame::Subset {
                index,
                indices: indices.into_iter().map(|i| i as u32).collect(),
            }
        }
        2 => Frame::meta(&random_metadata(rng)),
        3 => Frame::Error(format!("error #{}", rng.below(100))),
        4 => Frame::EpochAdvance {
            epoch: rng.next_u64() >> rng.below(64),
            n_subsets: rng.below(16) as u32,
        },
        _ => {
            let k = rng.below(120);
            Frame::SubsetDelta {
                epoch: 1 + rng.below(1_000_000) as u64,
                index: if rng.chance(0.2) {
                    frame::NO_INDEX
                } else {
                    rng.below(1000) as u32
                },
                indices: (0..k).map(|_| rng.below(u32::MAX as usize) as u32).collect(),
            }
        }
    }
}

#[test]
fn frames_roundtrip_through_arbitrary_split_boundaries() {
    check_cases(0xF8A3, 60, |seed| {
        let mut rng = Rng::new(seed);
        let frames: Vec<Frame> = (0..1 + rng.below(8)).map(|_| random_frame(&mut rng)).collect();
        let stream: Vec<u8> = frames.iter().flat_map(|f| f.encode()).collect();

        // feed the byte stream in random-sized chunks
        let mut decoder = FrameDecoder::new();
        let mut decoded = Vec::new();
        let mut pos = 0;
        while pos < stream.len() {
            let chunk = 1 + rng.below((stream.len() - pos).min(97));
            decoder.push(&stream[pos..pos + chunk]);
            pos += chunk;
            while let Some(f) = decoder.next().expect("valid stream must decode") {
                decoded.push(f);
            }
        }
        assert_eq!(decoded, frames, "split-delivery decode mismatch (seed {seed})");
        assert_eq!(decoder.pending_bytes(), 0);

        // byte-identical re-encode
        let re: Vec<u8> = decoded.iter().flat_map(|f| f.encode()).collect();
        assert_eq!(re, stream, "re-encode must be byte-identical (seed {seed})");
    });
}

#[test]
fn metadata_survives_the_meta_frame_byte_identically() {
    check_cases(0x4D45, 40, |seed| {
        let mut rng = Rng::new(seed);
        let meta = random_metadata(&mut rng);
        let f = Frame::meta(&meta);
        let wire = f.encode();
        let mut decoder = FrameDecoder::new();
        decoder.push(&wire);
        let back = decoder.next().unwrap().unwrap();
        let decoded = back.decode_meta().expect("served artifact must decode");
        assert_eq!(decoded, meta);
        // the served payload is exactly the store's binfmt artifact bytes
        assert_eq!(back, Frame::meta(&decoded));
    });
}

#[test]
fn truncation_never_yields_a_frame_and_never_panics() {
    check_cases(0x7421, 30, |seed| {
        let mut rng = Rng::new(seed);
        let frame = random_frame(&mut rng);
        let bytes = frame.encode();
        for cut in 0..bytes.len() {
            let mut d = FrameDecoder::new();
            d.push(&bytes[..cut]);
            match d.next() {
                Ok(None) => assert_eq!(d.pending_bytes(), cut, "partial must buffer"),
                Ok(Some(f)) => panic!("truncation to {cut} bytes decoded {f:?}"),
                // a cut that lands inside the header can legitimately be
                // detected as corrupt once 5 bytes are present — but only
                // as a clean error
                Err(_) => {}
            }
        }
    });
}

#[test]
fn corruption_is_a_clean_error_not_a_panic() {
    check_cases(0xC0FF, 30, |seed| {
        let mut rng = Rng::new(seed);
        let frame = random_frame(&mut rng);
        let mut bytes = frame.encode();
        let pos = rng.below(bytes.len());
        let flip = 1u8 << rng.below(8);
        bytes[pos] ^= flip;
        let mut d = FrameDecoder::new();
        d.push(&bytes);
        match d.next() {
            // most flips (length prefix, kind byte, SUBSET count) are
            // structural and must be detected...
            Err(_) | Ok(None) => {}
            // ...a payload-byte flip can decode to a *different* frame —
            // but a flipped META payload must then fail the binfmt
            // checksum rather than mis-parse
            Ok(Some(got @ Frame::Meta(_))) if matches!(frame, Frame::Meta(_)) => {
                assert!(
                    got.decode_meta().is_err(),
                    "bit-flipped artifact must fail the checksum (seed {seed}, pos {pos})"
                );
            }
            Ok(Some(_)) => {}
        }
    });
}

#[test]
fn a_corrupt_length_prefix_cannot_drive_allocation() {
    // a frame claiming a multi-GB payload must fail fast at the header,
    // not wait for (or allocate) the bogus payload. `u32::MAX` also sets
    // every stream bit — the stream id must not mask a bogus length
    let mut d = FrameDecoder::new();
    let mut bytes = vec![];
    bytes.extend_from_slice(&(u32::MAX).to_le_bytes());
    bytes.push(frame::KIND_SUBSET);
    d.push(&bytes);
    assert!(d.next().is_err());
}

// ---------------------------------------------------------------------------
// Stream-id properties (the multiplexed header)
// ---------------------------------------------------------------------------

#[test]
fn stream_tagged_frames_roundtrip_through_arbitrary_splits() {
    check_cases(0x5741, 60, |seed| {
        let mut rng = Rng::new(seed);
        let tagged: Vec<(u8, Frame)> = (0..1 + rng.below(8))
            .map(|_| (rng.below(frame::MAX_STREAMS) as u8, random_frame(&mut rng)))
            .collect();
        let stream: Vec<u8> =
            tagged.iter().flat_map(|(s, f)| f.encode_on(*s)).collect();

        let mut decoder = FrameDecoder::new();
        let mut decoded = Vec::new();
        let mut pos = 0;
        while pos < stream.len() {
            let chunk = 1 + rng.below((stream.len() - pos).min(97));
            decoder.push(&stream[pos..pos + chunk]);
            pos += chunk;
            while let Some(sf) =
                decoder.next_with_stream().expect("valid stream must decode")
            {
                decoded.push(sf);
            }
        }
        assert_eq!(decoded, tagged, "stream tags must survive split delivery");

        // byte-identical re-encode, tags included
        let re: Vec<u8> = decoded.iter().flat_map(|(s, f)| f.encode_on(*s)).collect();
        assert_eq!(re, stream, "stream-tagged re-encode must be byte-identical");
    });
}

#[test]
fn stream_zero_encoding_is_byte_identical_to_the_legacy_wire() {
    // the multiplexed header is backward compatible: stream 0 leaves all
    // five spare bits clear, so pre-multiplexing peers see the exact
    // bytes they always did
    check_cases(0x1E6A, 40, |seed| {
        let mut rng = Rng::new(seed);
        let f = random_frame(&mut rng);
        assert_eq!(f.encode(), f.encode_on(0), "encode() must be the stream-0 wire");
    });
}

#[test]
fn restreaming_a_burst_equals_encoding_it_on_that_stream() {
    // the server's push fan-out replays one pre-encoded stream-0 burst
    // per subscriber, rewriting only header stream bits — the result
    // must be byte-identical to encoding each frame on the target stream
    check_cases(0xBEE5, 40, |seed| {
        let mut rng = Rng::new(seed);
        let burst: Vec<Frame> =
            (0..1 + rng.below(6)).map(|_| random_frame(&mut rng)).collect();
        let base: Vec<u8> = burst.iter().flat_map(|f| f.encode()).collect();
        for _ in 0..3 {
            let s = rng.below(frame::MAX_STREAMS) as u8;
            let mut restreamed = Vec::new();
            frame::restream_frames(&base, &mut restreamed, s).unwrap();
            let direct: Vec<u8> = burst.iter().flat_map(|f| f.encode_on(s)).collect();
            assert_eq!(restreamed, direct, "restream to {s} diverged from direct encode");
        }
    });
}

#[test]
fn flipping_stream_bits_moves_a_frame_without_corrupting_it() {
    // the stream id occupies the header's top 5 bits: any flip there
    // re-routes the frame but must never change its length, kind, or
    // payload — the codec treats routing and content independently
    check_cases(0x0F11, 30, |seed| {
        let mut rng = Rng::new(seed);
        let f = random_frame(&mut rng);
        let mut bytes = f.encode_on(rng.below(frame::MAX_STREAMS) as u8);
        let bit = 32 - 5 + rng.below(5); // one of the header word's stream bits
        bytes[bit / 8] ^= 1 << (bit % 8);
        let word = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
        let want_stream = (word >> 27) as u8;

        let mut d = FrameDecoder::new();
        d.push(&bytes);
        let (s, got) = d
            .next_with_stream()
            .expect("stream bits are routing, not structure")
            .expect("complete frame");
        assert_eq!(s, want_stream);
        assert_eq!(got, f, "payload must be untouched by stream-bit flips");
        assert_eq!(d.pending_bytes(), 0);
    });
}
