//! Property tests for the future-work extensions (Gibbs exchange chain,
//! feature-based coverage functions) — seed sweeps over random instances.

use milo::submod::{
    coverage_features, featurebased::brute_force_coverage, functions::brute_force_value,
    gibbs_class_subsets, greedy_maximize, sample_importance, FeatureCoverage,
    GibbsSampler, GreedyMode, SetFunction, SetFunctionKind,
};
use milo::testkit::{check_cases, clustered_kernel, random_embeddings, random_kernel};
use milo::util::rng::Rng;

const KINDS: [SetFunctionKind; 4] = [
    SetFunctionKind::FacilityLocation,
    SetFunctionKind::GraphCut { lambda: 0.4 },
    SetFunctionKind::DisparitySum,
    SetFunctionKind::DisparityMin,
];

// ---------------------------------------------------------------------------
// Gibbs exchange chain
// ---------------------------------------------------------------------------

#[test]
fn prop_gibbs_preserves_cardinality_and_membership() {
    check_cases(300, 15, |seed| {
        let n = 10 + (seed % 20) as usize;
        let s = random_kernel(n, seed);
        let mut rng = Rng::new(seed ^ 2);
        let k = 2 + rng.below((n - 2).min(8));
        for kind in KINDS {
            let mut chain = GibbsSampler::new(&s, kind, 2.0, k, &mut rng);
            for _ in 0..80 {
                chain.step(&mut rng);
                assert_eq!(chain.k(), k, "{kind:?} n={n} k={k}");
                let mut st = chain.state().to_vec();
                st.sort_unstable();
                st.dedup();
                assert_eq!(st.len(), k, "duplicate members: {kind:?}");
                assert!(st.iter().all(|&i| i < n));
            }
        }
    });
}

#[test]
fn prop_gibbs_cached_value_stays_exact() {
    check_cases(301, 12, |seed| {
        let n = 8 + (seed % 12) as usize;
        let s = random_kernel(n, seed);
        let mut rng = Rng::new(seed ^ 3);
        let k = 2 + rng.below((n - 2).min(6));
        for kind in KINDS {
            let mut chain = GibbsSampler::new(&s, kind, 1.5, k, &mut rng);
            for _ in 0..60 {
                chain.step(&mut rng);
            }
            let brute = brute_force_value(kind, &s, chain.state());
            assert!(
                (chain.value() - brute).abs() < 1e-2 * (1.0 + brute.abs()),
                "{kind:?} n={n} k={k}: cached {} vs brute {brute}",
                chain.value()
            );
        }
    });
}

#[test]
fn prop_gibbs_stationary_value_beats_uniform_start() {
    // a moderately hot chain should, on average, end above its random
    // initial value for monotone representation functions
    check_cases(302, 10, |seed| {
        let n = 24;
        let (s, _) = clustered_kernel(n, 4, 0.85, 0.15, seed);
        let mut rng = Rng::new(seed ^ 4);
        let mut chain =
            GibbsSampler::new(&s, SetFunctionKind::FacilityLocation, 20.0, 5, &mut rng);
        let start = chain.value();
        for _ in 0..300 {
            chain.step(&mut rng);
        }
        assert!(
            chain.value() >= start - 1e-4,
            "seed {seed}: {} -> {}",
            start,
            chain.value()
        );
    });
}

#[test]
fn prop_gibbs_class_subsets_are_valid_partitioned_subsets() {
    check_cases(303, 10, |seed| {
        let mut rng = Rng::new(seed ^ 5);
        let n1 = 8 + rng.below(10);
        let n2 = 8 + rng.below(10);
        let k1 = random_kernel(n1, seed);
        let k2 = random_kernel(n2, seed ^ 6);
        let idx1: Vec<usize> = (0..n1).collect();
        let idx2: Vec<usize> = (n1..n1 + n2).collect();
        let a1 = 1 + rng.below(n1 - 1);
        let a2 = 1 + rng.below(n2 - 1);
        let (subsets, stats) = gibbs_class_subsets(
            &[(&k1, &idx1), (&k2, &idx2)],
            &[a1, a2],
            SetFunctionKind::GRAPH_CUT_DEFAULT,
            3.0,
            30,
            3,
            3,
            &mut rng,
        );
        assert_eq!(subsets.len(), 3);
        for s in &subsets {
            assert_eq!(s.len(), a1 + a2);
            assert_eq!(s.iter().filter(|&&i| i < n1).count(), a1);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
        assert!(stats.evaluations >= stats.proposals);
    });
}

#[test]
fn gibbs_determinism_under_same_seed() {
    let s = random_kernel(20, 77);
    let run = |seed: u64| {
        let mut rng = Rng::new(seed);
        let mut chain =
            GibbsSampler::new(&s, SetFunctionKind::GRAPH_CUT_DEFAULT, 4.0, 6, &mut rng);
        chain.sample(50, 5, 3, &mut rng)
    };
    assert_eq!(run(9), run(9));
    assert_ne!(run(9), run(10));
}

// ---------------------------------------------------------------------------
// Feature-based coverage
// ---------------------------------------------------------------------------

#[test]
fn prop_coverage_incremental_matches_brute_force() {
    check_cases(310, 15, |seed| {
        let n = 10 + (seed % 25) as usize;
        let e = 3 + (seed % 6) as usize;
        let z = random_embeddings(n, e, seed);
        let phi = coverage_features(&z);
        let mut f = FeatureCoverage::new(&phi);
        let mut rng = Rng::new(seed ^ 7);
        let k = 1 + rng.below(n.min(8));
        let picks = rng.sample_indices(n, k);
        for &j in &picks {
            f.add(j);
        }
        let brute = brute_force_coverage(&phi, &picks);
        assert!(
            (f.value() - brute).abs() < 1e-3 * (1.0 + brute.abs()),
            "n={n} e={e}: {} vs {brute}",
            f.value()
        );
    });
}

#[test]
fn prop_coverage_is_monotone_submodular() {
    check_cases(311, 15, |seed| {
        let n = 12 + (seed % 14) as usize;
        let z = random_embeddings(n, 4, seed);
        let phi = coverage_features(&z);
        let mut rng = Rng::new(seed ^ 8);
        let probe = rng.below(n);
        let mut f = FeatureCoverage::new(&phi);
        let mut last = f.gain(probe);
        assert!(last >= 0.0);
        let adds = rng.sample_indices(n, n.min(7));
        for &j in adds.iter().filter(|&&j| j != probe) {
            f.add(j);
            let g = f.gain(probe);
            assert!(g >= -1e-6, "negative gain {g}");
            assert!(g <= last + 1e-5, "gain grew {last} -> {g}");
            last = g;
        }
    });
}

#[test]
fn prop_coverage_greedy_beats_random_subsets() {
    check_cases(312, 10, |seed| {
        let n = 40;
        let z = random_embeddings(n, 6, seed);
        let phi = coverage_features(&z);
        let mut rng = Rng::new(seed ^ 9);
        let k = 8;
        let mut f = FeatureCoverage::new(&phi);
        let trace = greedy_maximize(&mut f, k, GreedyMode::Naive, true, &mut rng);
        let greedy_val = brute_force_coverage(&phi, &trace.selected);
        // greedy ≥ the best of 20 random subsets (1−1/e guarantee makes
        // this overwhelmingly likely at these sizes)
        let mut best_rand = 0.0f32;
        for _ in 0..20 {
            let r = rng.sample_indices(n, k);
            best_rand = best_rand.max(brute_force_coverage(&phi, &r));
        }
        assert!(
            greedy_val >= best_rand - 1e-3,
            "greedy {greedy_val} < best random {best_rand}"
        );
    });
}

#[test]
fn prop_coverage_importance_sweep_is_complete_permutation_weighting() {
    check_cases(313, 10, |seed| {
        let n = 10 + (seed % 15) as usize;
        let z = random_embeddings(n, 5, seed);
        let phi = coverage_features(&z);
        let mut f = FeatureCoverage::new(&phi);
        let gains = sample_importance(&mut f, true);
        assert_eq!(gains.len(), n);
        // every gain is finite and non-negative; the first (largest
        // greedy pick) dominates the last
        for &g in &gains {
            assert!(g.is_finite() && g >= -1e-6);
        }
        let mx = gains.iter().cloned().fold(f32::MIN, f32::max);
        let mn = gains.iter().cloned().fold(f32::MAX, f32::min);
        assert!(mx >= mn);
    });
}

#[test]
fn prop_lazy_and_naive_greedy_agree_for_coverage() {
    check_cases(314, 12, |seed| {
        let n = 15 + (seed % 10) as usize;
        let z = random_embeddings(n, 4, seed);
        let phi = coverage_features(&z);
        let k = 5;
        let mut rng = Rng::new(seed);
        let mut f1 = FeatureCoverage::new(&phi);
        let naive = greedy_maximize(&mut f1, k, GreedyMode::Naive, true, &mut rng);
        let mut f2 = FeatureCoverage::new(&phi);
        let lazy = greedy_maximize(&mut f2, k, GreedyMode::Lazy, true, &mut rng);
        let nv = brute_force_coverage(&phi, &naive.selected);
        let lv = brute_force_coverage(&phi, &lazy.selected);
        assert!(
            (nv - lv).abs() < 1e-3 * (1.0 + nv.abs()),
            "naive {nv} vs lazy {lv}"
        );
    });
}

#[test]
fn coverage_features_of_clustered_embeddings_separate_clusters() {
    // samples in the same direction share coverage mass: greedy picks
    // spread across clusters rather than duplicating one
    let n = 30;
    let mut z = milo::tensor::Matrix::zeros(n, 6);
    for i in 0..n {
        let c = i % 3;
        for d in 0..6 {
            let base = if d == 2 * c { 1.0 } else { 0.05 };
            z.set(i, d, base + 0.01 * (i as f32));
        }
    }
    z.l2_normalize_rows();
    let phi = coverage_features(&z);
    let mut f = FeatureCoverage::new(&phi);
    let mut rng = Rng::new(1);
    let trace = greedy_maximize(&mut f, 3, GreedyMode::Naive, true, &mut rng);
    let clusters: std::collections::HashSet<usize> =
        trace.selected.iter().map(|&i| i % 3).collect();
    assert_eq!(clusters.len(), 3, "greedy should cover all 3 clusters");
}
