//! Property tests for the metadata store's persistence layer: the binary
//! round-trip must be byte-identical across random metadata instances, and
//! every corruption mode (truncation, bit flips, garbage, stale schema)
//! must surface as a clean error — never a panic, never a silently wrong
//! selection.

use milo::coordinator::{metadata_from_json, metadata_to_json, Metadata};
use milo::selection::milo::ClassProbs;
use milo::store::{binfmt, MetaKey, MetaStore};
use milo::testkit::check_cases;
use milo::util::json::Json;
use milo::util::rng::Rng;

/// Random but structurally valid metadata: variable class counts/sizes,
/// subset counts, and probability mass (normalized per class).
fn random_metadata(seed: u64) -> Metadata {
    let mut rng = Rng::new(seed);
    let classes = 1 + rng.below(5);
    let n_per = 5 + rng.below(60);
    let n = classes * n_per;
    let n_subsets = 1 + rng.below(4);
    let k = 1 + rng.below(n);
    let wre_classes: Vec<ClassProbs> = (0..classes)
        .map(|c| {
            let raw: Vec<f64> = (0..n_per).map(|_| rng.f64() + 1e-6).collect();
            let total: f64 = raw.iter().sum();
            ClassProbs {
                indices: (c * n_per..(c + 1) * n_per).collect(),
                probs: raw.into_iter().map(|p| p / total).collect(),
            }
        })
        .collect();
    Metadata {
        dataset: format!("ds_{}", seed % 97),
        fraction: rng.f64(),
        sge_subsets: (0..n_subsets).map(|_| rng.sample_indices(n, k)).collect(),
        wre_classes,
        fixed_dm: rng.sample_indices(n, k),
        preprocess_secs: rng.f64() * 100.0,
    }
}

#[test]
fn prop_roundtrip_is_byte_identical() {
    check_cases(2024, 40, |seed| {
        let meta = random_metadata(seed);
        let bytes = binfmt::encode(&meta);
        let decoded = binfmt::decode(&bytes).expect("decode of fresh encode");
        assert_eq!(decoded, meta, "decode must reproduce every field exactly");
        // save -> load -> save: the second save is byte-identical
        assert_eq!(binfmt::encode(&decoded), bytes);
    });
}

#[test]
fn prop_store_file_roundtrip_is_byte_identical() {
    let dir = std::env::temp_dir()
        .join(format!("milo_store_props_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = MetaStore::open(&dir).unwrap();
    check_cases(77, 10, |seed| {
        let meta = random_metadata(seed);
        let mut key = MetaKey::from_options(
            &meta.dataset,
            &milo::coordinator::PreprocessOptions::default(),
        );
        key.seed = seed;
        store.put(&key, meta.clone()).unwrap();
        let first = std::fs::read(store.path_for(&key)).unwrap();
        // load through a cold handle, save again, compare bytes
        let cold = MetaStore::open(&dir).unwrap();
        let loaded = cold.load_uncached(&key).unwrap().expect("artifact exists");
        assert_eq!(loaded, meta);
        cold.put(&key, loaded).unwrap();
        let second = std::fs::read(store.path_for(&key)).unwrap();
        assert_eq!(first, second, "save -> load -> save must be byte-identical");
    });
    std::fs::remove_dir_all(&dir).ok();
}

/// The sparse-kernel width is part of the content address: a sparse
/// artifact (an approximation for knn < n_c) must never alias a dense
/// one, and each knn gets its own slot.
#[test]
fn prop_knn_is_part_of_the_address() {
    check_cases(0x5A5A, 20, |seed| {
        let opts = milo::coordinator::PreprocessOptions {
            seed,
            ..Default::default()
        };
        let dense = MetaKey::from_options("trec6", &opts);
        let opts = milo::coordinator::PreprocessOptions {
            knn: Some(1 + (seed % 256) as usize),
            ..opts
        };
        let sparse = MetaKey::from_options("trec6", &opts);
        assert_ne!(dense.fingerprint(), sparse.fingerprint(), "seed {seed}");
        let wider = MetaKey {
            knn: sparse.knn.map(|k| k + 1),
            ..sparse.clone()
        };
        assert_ne!(sparse.fingerprint(), wider.fingerprint(), "seed {seed}");
        // same width → same address (the amortization still holds)
        assert_eq!(
            sparse.fingerprint(),
            MetaKey::from_options("trec6", &opts).fingerprint()
        );
    });
}

/// Cross-codec equivalence: the JSON codec (`save_metadata` /
/// `load_metadata` / the serve protocol's `GET_META`) and the store's
/// binfmt must reconstruct *identical* `Metadata` for the same input —
/// any silent field drift between the two serializers shows up here as a
/// byte-level mismatch.
#[test]
fn prop_json_and_binfmt_codecs_agree_exactly() {
    check_cases(0xC0DEC, 40, |seed| {
        let meta = random_metadata(seed);

        // JSON text round-trip (shortest-float formatting is exact)
        let text = metadata_to_json(&meta).to_string();
        let via_json =
            metadata_from_json(&Json::parse(&text).expect("codec JSON parses"))
                .expect("codec JSON decodes");

        // binary round-trip
        let via_bin =
            binfmt::decode(&binfmt::encode(&meta)).expect("binfmt decodes");

        assert_eq!(via_json, meta, "JSON codec drifted from the source");
        assert_eq!(via_bin, meta, "binfmt codec drifted from the source");
        assert_eq!(via_json, via_bin, "the two codecs disagree");
        // and at byte level: re-encoding either reconstruction is identical
        assert_eq!(binfmt::encode(&via_json), binfmt::encode(&via_bin));
        assert_eq!(metadata_to_json(&via_bin).to_string(), text);
    });
}

#[test]
fn prop_truncations_and_flips_error_cleanly() {
    check_cases(4096, 12, |seed| {
        let meta = random_metadata(seed);
        let bytes = binfmt::encode(&meta);
        let mut rng = Rng::new(seed ^ 0xC0FF_EE);
        for _ in 0..16 {
            let cut = rng.below(bytes.len());
            assert!(
                binfmt::decode(&bytes[..cut]).is_err(),
                "truncation to {cut}/{} bytes must fail",
                bytes.len()
            );
            let mut flipped = bytes.clone();
            let pos = rng.below(bytes.len());
            flipped[pos] ^= 1u8 << rng.below(8);
            assert!(
                binfmt::decode(&flipped).is_err(),
                "bit flip at byte {pos} must fail the checksum"
            );
        }
    });
}

#[test]
fn garbage_files_error_cleanly_and_store_rebuilds() {
    let dir = std::env::temp_dir()
        .join(format!("milo_store_garbage_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = MetaStore::open(&dir).unwrap();
    let key = MetaKey::from_options(
        "garbage",
        &milo::coordinator::PreprocessOptions::default(),
    );

    for garbage in [
        &b""[..],
        &b"MILOSTOR"[..], // magic only
        &b"{\"this\": \"is json, not binfmt\"}"[..],
        &[0u8; 64][..],
    ] {
        std::fs::write(store.path_for(&key), garbage).unwrap();
        let cold = MetaStore::open(&dir).unwrap();
        assert!(
            cold.load_uncached(&key).is_err(),
            "{} garbage bytes must be a clean load error",
            garbage.len()
        );
        // ...and get_or_build self-heals by rebuilding
        let rebuilt = cold
            .get_or_build(&key, || Ok(random_metadata(1)))
            .unwrap();
        assert_eq!(*rebuilt, random_metadata(1));
        assert_eq!(cold.stats().builds, 1);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_schema_version_is_rebuilt_not_misparsed() {
    let dir = std::env::temp_dir()
        .join(format!("milo_store_stale_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = MetaStore::open(&dir).unwrap();
    let key = MetaKey::from_options(
        "stale",
        &milo::coordinator::PreprocessOptions::default(),
    );
    // forge a valid-checksum artifact with a future schema version
    let mut bytes = binfmt::encode(&random_metadata(9));
    bytes[8..12].copy_from_slice(&(binfmt::FORMAT_VERSION + 7).to_le_bytes());
    let n = bytes.len();
    let check = milo::store::fnv1a64(&bytes[..n - 8]);
    bytes[n - 8..].copy_from_slice(&check.to_le_bytes());
    std::fs::write(store.path_for(&key), &bytes).unwrap();

    let err = store.load_uncached(&key).unwrap_err();
    assert!(format!("{err:#}").contains("version"), "{err:#}");
    let rebuilt = store.get_or_build(&key, || Ok(random_metadata(2))).unwrap();
    assert_eq!(*rebuilt, random_metadata(2));
    assert_eq!(store.stats().builds, 1);
    std::fs::remove_dir_all(&dir).ok();
}
