//! The session API's core contract, end to end: the *same* `MiloSession`
//! driven through all three `MetaSource` variants — inline preprocessing,
//! the content-addressed store, and a live `milo serve` instance — must
//! resolve byte-identical `Metadata` (binfmt encoding compared) and
//! produce identical first-R-epoch subset streams.
//!
//! The store/remote half runs without AOT artifacts (metadata is
//! synthesized into a store and served); the inline leg joins when the
//! artifacts exist.

use milo::coordinator::{Metadata, PreprocessOptions, StrategyKind};
use milo::data::{Dataset, DatasetId};
use milo::kernel::SimilarityBackend;
use milo::selection::SelectCtx;
use milo::serve::SubsetServer;
use milo::session::{MetaSource, MiloSession};
use milo::store::{binfmt, MetaKey, MetaStore};
use milo::testkit::synthetic_metadata;
use milo::util::rng::Rng;

const SEED: u64 = 5;
const FRACTION: f64 = 0.1;
const EPOCHS: usize = 6;

fn dataset() -> Dataset {
    DatasetId::Trec6Like.generate(SEED)
}

fn options() -> PreprocessOptions {
    PreprocessOptions {
        fraction: FRACTION,
        backend: SimilarityBackend::Native,
        seed: SEED,
        ..Default::default()
    }
}

/// Build a session over `source` (runtime optional).
fn session(rt: Option<&milo::runtime::Runtime>, source: MetaSource) -> MiloSession<'_> {
    let builder = MiloSession::builder()
        .dataset(dataset())
        .source(source)
        .fraction(FRACTION)
        .seed(SEED);
    match rt {
        Some(rt) => builder.runtime(rt).build().unwrap(),
        None => builder.build().unwrap(),
    }
}

/// The first R-epoch subset stream of the session's MILO strategy, under a
/// fixed selection RNG — a pure function of the resolved metadata.
fn subset_stream(session: &MiloSession<'_>) -> Vec<Vec<usize>> {
    let mut strat = session
        .strategy(StrategyKind::Milo { kappa: 1.0 / 6.0 })
        .expect("milo strategy off the session");
    let ds = session.dataset();
    let k = session.k();
    let mut rng = Rng::new(0xDEC1);
    let mut stream = Vec::with_capacity(EPOCHS);
    for epoch in 0..EPOCHS {
        let mut ctx = SelectCtx::model_agnostic(ds, epoch, EPOCHS, k, &mut rng);
        stream.push(strat.select(&mut ctx).expect("select"));
    }
    stream
}

fn encoded(meta: &Metadata) -> Vec<u8> {
    binfmt::encode(meta)
}

#[test]
fn same_session_identical_across_store_and_serve_sources() {
    // artifact-free legs: synthesized metadata in a store, then served
    let dir = std::env::temp_dir()
        .join(format!("milo_session_sources_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let ds = dataset();
    let store = MetaStore::open(&dir).unwrap();
    let key = MetaKey::from_options(ds.name(), &options());
    store.put(&key, synthetic_metadata(&ds, FRACTION)).unwrap();

    // store-backed session (cold handle below proves the disk path too)
    let store_session =
        session(None, MetaSource::store_handle(store.clone(), options()));
    let store_meta = store_session.metadata().unwrap();

    // served session over the same artifact
    let server =
        SubsetServer::bind("127.0.0.1:0", store_meta.clone(), Some(store.clone()), SEED)
            .unwrap();
    let remote_session = session(
        None,
        MetaSource::remote_expecting(server.addr().to_string(), SEED, FRACTION),
    );
    let remote_meta = remote_session.metadata().unwrap();

    // byte-identical resolution…
    assert_eq!(
        encoded(&store_meta),
        encoded(&remote_meta),
        "store and served resolutions must be byte-identical"
    );
    // …and identical subset streams
    assert_eq!(subset_stream(&store_session), subset_stream(&remote_session));

    // a cold store handle (fresh LRU) decodes the same bytes from disk
    let cold_session = session(
        None,
        MetaSource::store_handle(MetaStore::open(&dir).unwrap(), options()),
    );
    assert_eq!(encoded(&cold_session.metadata().unwrap()), encoded(&store_meta));
    assert_eq!(subset_stream(&cold_session), subset_stream(&store_session));

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn same_session_identical_across_all_three_sources() {
    // the full three-way leg needs the AOT artifacts for the inline pass
    let Some(rt) = milo::testkit::artifacts_or_skip() else { return };
    let dir = std::env::temp_dir()
        .join(format!("milo_session_threeway_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    // 1. inline: the one real preprocessing pass
    let inline_session = session(Some(&rt), MetaSource::inline(options()));
    let inline_meta = inline_session.metadata().unwrap();

    // 2. store: publish that pass (precompute-once topology), resolve from
    //    a cold handle so the bytes genuinely come off disk
    let ds = dataset();
    let store = MetaStore::open(&dir).unwrap();
    let key = MetaKey::from_options(ds.name(), &options());
    store.put(&key, Metadata::clone(&inline_meta)).unwrap();
    let store_session = session(
        Some(&rt),
        MetaSource::store_handle(MetaStore::open(&dir).unwrap(), options()),
    );
    let store_meta = store_session.metadata().unwrap();

    // 3. remote: a live `milo serve` over the same artifact
    let server =
        SubsetServer::bind("127.0.0.1:0", store_meta.clone(), Some(store), SEED)
            .unwrap();
    let remote_session = session(
        None, // served consumption needs no runtime at all
        MetaSource::remote_expecting(server.addr().to_string(), SEED, FRACTION),
    );
    let remote_meta = remote_session.metadata().unwrap();

    // resolved metadata is byte-identical across all three sources
    let reference = encoded(&inline_meta);
    assert_eq!(reference, encoded(&store_meta), "inline vs store");
    assert_eq!(reference, encoded(&remote_meta), "inline vs served");

    // and the first R-epoch subset stream is identical
    let reference_stream = subset_stream(&inline_session);
    assert_eq!(reference_stream, subset_stream(&store_session), "store stream");
    assert_eq!(reference_stream, subset_stream(&remote_session), "served stream");

    // an independently *built* store resolution reproduces the selection
    // payload exactly (wall-clock provenance aside)
    let dir2 = std::env::temp_dir()
        .join(format!("milo_session_threeway_rebuild_{}", std::process::id()));
    std::fs::remove_dir_all(&dir2).ok();
    let rebuilt_session = session(
        Some(&rt),
        MetaSource::store(&dir2, options()).unwrap(),
    );
    let rebuilt = rebuilt_session.metadata().unwrap();
    assert_eq!(rebuilt.sge_subsets, inline_meta.sge_subsets);
    assert_eq!(rebuilt.fixed_dm, inline_meta.fixed_dm);
    assert_eq!(rebuilt.wre_classes, inline_meta.wre_classes);

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}
