//! Integration test for the store + serve subsystem (no AOT artifacts
//! required — metadata is synthesized, which is exactly the point: the
//! store/serve layers are model- and runtime-agnostic).
//!
//! Asserts the subsystem's two contracts end-to-end:
//!   (a) N concurrent consumers trigger exactly one preprocessing pass
//!       (store build count == 1);
//!   (b) each client's subset stream is a deterministic function of
//!       (server seed, client id) — identical on reconnect and identical
//!       across a server restart from the persisted store artifact.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use milo::coordinator::Metadata;
use milo::selection::milo::ClassProbs;
use milo::serve::{ClientOptions, ServeClient, SubsetServer, WireMode};
use milo::store::{MetaKey, MetaStore};

const N_CLIENTS: usize = 5;
const SGE_DRAWS: usize = 7;
const WRE_DRAWS: usize = 3;
const WRE_K: usize = 24;
const SEED: u64 = 42;

fn synthetic_metadata() -> Metadata {
    // 4 classes × 120 points, 3 SGE subsets — large enough that two
    // distinct WRE streams colliding is statistically impossible.
    let n_per = 120;
    let classes = 4;
    Metadata {
        dataset: "synthetic".into(),
        fraction: 0.1,
        sge_subsets: (0..3)
            .map(|r| {
                let mut s: Vec<usize> =
                    (0..48).map(|i| (i * 11 + r * 7) % (classes * n_per)).collect();
                s.sort_unstable();
                s.dedup();
                s
            })
            .collect(),
        wre_classes: (0..classes)
            .map(|c| ClassProbs {
                indices: (c * n_per..(c + 1) * n_per).collect(),
                probs: (0..n_per).map(|i| 1.0 + (i % 13) as f64).collect(),
            })
            .collect(),
        fixed_dm: (0..48).map(|i| i * 10).collect(),
        preprocess_secs: 0.01,
    }
}

fn test_key() -> MetaKey {
    MetaKey {
        dataset: "synthetic".into(),
        encoder: "default".into(),
        sge_function: "graph_cut_l0.4".into(),
        wre_function: "disparity_min".into(),
        fraction: 0.1,
        n_subsets: 3,
        epsilon: 0.01,
        seed: SEED,
        metric: "cosine".into(),
        backend: "native".into(),
        pipeline: "kernel".into(),
        knn: None,
        epoch: None,
    }
}

/// One client's full draw over `wire`: SGE cycle indices+subsets, then
/// WRE samples.
fn draw_stream_wire(
    addr: &str,
    client_id: &str,
    wire: WireMode,
) -> (Vec<(usize, Vec<usize>)>, Vec<Vec<usize>>) {
    let mut client = ServeClient::connect_with(
        addr,
        client_id,
        ClientOptions { wire, ..Default::default() },
    )
    .unwrap();
    let sge: Vec<(usize, Vec<usize>)> =
        (0..SGE_DRAWS).map(|_| client.next_subset().unwrap()).collect();
    let wre: Vec<Vec<usize>> =
        (0..WRE_DRAWS).map(|_| client.sample_wre(WRE_K).unwrap()).collect();
    (sge, wre)
}

fn draw_stream(addr: &str, client_id: &str) -> (Vec<(usize, Vec<usize>)>, Vec<Vec<usize>>) {
    draw_stream_wire(addr, client_id, WireMode::Json)
}

#[test]
fn concurrent_clients_share_one_preprocess_and_streams_survive_restart() {
    let dir = std::env::temp_dir()
        .join(format!("milo_serve_it_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = MetaStore::open(&dir).unwrap();
    let key = test_key();

    // -- (a) exactly one preprocessing pass under concurrent demand ------
    let builds = Arc::new(AtomicU64::new(0));
    std::thread::scope(|scope| {
        for _ in 0..N_CLIENTS {
            let store = store.clone();
            let key = key.clone();
            let builds = builds.clone();
            scope.spawn(move || {
                store
                    .get_or_build(&key, || {
                        builds.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        Ok(synthetic_metadata())
                    })
                    .unwrap();
            });
        }
    });
    assert_eq!(builds.load(Ordering::SeqCst), 1, "preprocess must run once");
    assert_eq!(store.stats().builds, 1);

    let meta = store
        .get_or_build(&key, || panic!("metadata must already be in the store"))
        .unwrap();

    // -- serve on an ephemeral port, ≥4 concurrent clients ---------------
    let server =
        SubsetServer::bind("127.0.0.1:0", meta.clone(), Some(store.clone()), SEED)
            .unwrap();
    let addr = server.addr().to_string();

    let mut first_run: Vec<(String, (Vec<(usize, Vec<usize>)>, Vec<Vec<usize>>))> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..N_CLIENTS)
                .map(|c| {
                    let addr = addr.clone();
                    scope.spawn(move || {
                        // alternate wire modes: stream content must not
                        // depend on the transport encoding
                        let wire =
                            if c % 2 == 0 { WireMode::Json } else { WireMode::Frame };
                        let id = format!("client-{c}");
                        let stream = draw_stream_wire(&addr, &id, wire);
                        (id, stream)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
    first_run.sort_by(|a, b| a.0.cmp(&b.0));

    // every subset the server handed out is well-formed
    for (id, (sge, wre)) in &first_run {
        assert_eq!(sge.len(), SGE_DRAWS, "{id}");
        for (index, subset) in sge {
            assert!(*index < meta.sge_subsets.len(), "{id}");
            assert_eq!(subset, &meta.sge_subsets[*index], "{id}");
        }
        for draw in wre {
            assert_eq!(draw.len(), WRE_K, "{id}");
            let mut d = draw.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), WRE_K, "{id}: WRE draw has duplicates");
        }
    }

    // distinct clients draw distinct (non-overlapping) WRE streams
    for i in 0..first_run.len() {
        for j in (i + 1)..first_run.len() {
            assert_ne!(
                first_run[i].1 .1, first_run[j].1 .1,
                "{} and {} share a WRE stream",
                first_run[i].0, first_run[j].0
            );
        }
    }

    // deterministic on reconnect: same id, same server -> same stream
    for (id, stream) in &first_run {
        assert_eq!(&draw_stream(&addr, id), stream, "{id} replay differs");
    }

    // the server's STATS sees the single store build and the traffic
    // (the "store" field is the store registry's JSON rendering — dotted
    // metric names, histograms as summary objects)
    let mut probe = ServeClient::connect(&addr, "probe").unwrap();
    let stats = probe.stats().unwrap();
    let store_stats = stats.get("store").unwrap();
    assert_eq!(store_stats.get("store.builds").unwrap().as_usize().unwrap(), 1);
    assert_eq!(
        store_stats
            .get("store.build_latency_ns")
            .unwrap()
            .get("count")
            .unwrap()
            .as_usize()
            .unwrap(),
        1,
        "the one build must have recorded one build latency"
    );
    assert!(
        stats.get("subsets_served").unwrap().as_usize().unwrap()
            >= 2 * N_CLIENTS * SGE_DRAWS
    );
    drop(probe);
    server.shutdown();

    // -- (b) restart from the persisted artifact: identical streams ------
    let store2 = MetaStore::open(&dir).unwrap(); // cold LRU, warm disk
    let meta2 = store2
        .get_or_build(&key, || panic!("restart must load from the store, not rebuild"))
        .unwrap();
    assert_eq!(*meta2, *meta, "persisted metadata must round-trip exactly");
    assert_eq!(store2.stats().builds, 0);
    assert_eq!(store2.stats().disk_loads, 1);

    let server2 =
        SubsetServer::bind("127.0.0.1:0", meta2, Some(store2), SEED).unwrap();
    let addr2 = server2.addr().to_string();
    for (id, stream) in &first_run {
        assert_eq!(
            &draw_stream(&addr2, id),
            stream,
            "{id} stream changed across server restart"
        );
    }
    server2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn server_rejects_malformed_requests_without_dying() {
    use std::io::{BufRead, BufReader, Write};

    let meta = Arc::new(synthetic_metadata());
    let server = SubsetServer::bind("127.0.0.1:0", meta, None, 1).unwrap();
    let addr = server.addr().to_string();

    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());
    for (bad, expect) in [
        ("this is not json", "bad request"),
        ("{\"nocmd\":1}", "cmd"),
        ("{\"cmd\":\"WAT\"}", "unknown cmd"),
        ("{\"cmd\":\"SAMPLE_WRE\"}", "k"),
    ] {
        raw.write_all(format!("{bad}\n").as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(
            line.contains("\"ok\":false") && line.contains(expect),
            "request {bad:?} -> {line:?}"
        );
    }
    // the connection (and server) still works afterwards
    raw.write_all(b"{\"cmd\":\"PING\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line:?}");
    drop(raw);

    let mut client = ServeClient::connect(&addr, "after-garbage").unwrap();
    assert_eq!(client.next_subset().unwrap().1.len(), 48);
    server.shutdown();
}

#[test]
fn server_rejects_corrupt_frames_without_dying() {
    use milo::serve::frame::{Frame, FrameDecoder};
    use std::io::{Read, Write};

    let meta = Arc::new(synthetic_metadata());
    let server = SubsetServer::bind("127.0.0.1:0", meta, None, 1).unwrap();
    let addr = server.addr().to_string();

    // negotiate frame mode by hand, then send a corrupt frame header
    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    raw.write_all(b"{\"cmd\":\"HELLO\",\"client\":\"vandal\",\"wire\":\"frame\"}\n")
        .unwrap();
    let mut hello = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        raw.read_exact(&mut byte).unwrap();
        if byte[0] == b'\n' {
            break;
        }
        hello.push(byte[0]);
    }
    assert!(String::from_utf8_lossy(&hello).contains("\"wire\":\"frame\""));

    // a frame with an unknown kind: the server answers with an ERROR
    // frame and closes this connection — but keeps serving others
    raw.write_all(&[3, 0, 0, 0, 250, 1, 2, 3]).unwrap();
    let mut response = Vec::new();
    raw.read_to_end(&mut response).unwrap(); // server closes after the error
    let mut decoder = FrameDecoder::new();
    decoder.push(&response);
    match decoder.next().unwrap() {
        Some(Frame::Error(msg)) => assert!(msg.contains("frame"), "{msg}"),
        other => panic!("expected an ERROR frame, got {other:?}"),
    }
    drop(raw);

    let mut client = ServeClient::connect(&addr, "after-vandal").unwrap();
    assert_eq!(client.next_subset().unwrap().1.len(), 48);
    server.shutdown();
}
