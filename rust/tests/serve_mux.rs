//! Stream-multiplexing acceptance tests: a fleet of logical sessions
//! pooled onto shared framed connections must be **indistinguishable on
//! the wire** from the same fleet holding one dedicated connection each.
//! Asserts the ISSUE's byte-identity criterion across all three traffic
//! classes:
//!
//!   (a) metadata — `GET_META` over a pooled stream returns the exact
//!       binfmt artifact bytes a dedicated connection returns;
//!   (b) deterministic subset streams — `NEXT_SUBSET` / `SAMPLE_WRE`
//!       draws on a pooled stream replay the dedicated connection's
//!       streams draw-for-draw (they are functions of `(seed, entry,
//!       client id)`, never of the transport);
//!   (c) push delivery — a publish reaches every subscribed stream on a
//!       shared connection with the same reassembled `EpochUpdate` a
//!       dedicated subscriber gets, even when sibling pushes interleave.
//!
//! Plus the multiplexing win itself: N sessions ride `⌈N/31⌉` sockets
//! (stream 0 is the pool's control session), per-stream `GOODBYE` frees
//! the stream id without closing the shared socket, and entry routing
//! binds different streams of one socket to different datasets.

use std::sync::Arc;

use milo::continual::{ContinualOptions, ContinualSelector};
use milo::coordinator::Metadata;
use milo::serve::{
    frame, ClientOptions, ConnectionPool, ServeClient, SubsetServer, WireMode,
};
use milo::store::binfmt;
use milo::testkit::random_embeddings;

const SEED: u64 = 23;
const CLASSES: usize = 3;
const DIM: usize = 6;

/// One continual-epoch metadata instance for `dataset` (distinct
/// embedding seeds so distinct datasets carry distinct subsets).
fn meta_for(dataset: &str, embed_seed: u64) -> Arc<Metadata> {
    let mut opts = ContinualOptions::new(dataset);
    opts.seed = SEED;
    opts.knn = Some(4);
    let mut sel = ContinualSelector::new(opts);
    let z = random_embeddings(30, DIM, embed_seed);
    for i in 0..30 {
        sel.arrive(i % CLASSES, z.row(i)).unwrap();
    }
    let (meta, _) = sel.advance_epoch().unwrap();
    Arc::new(meta)
}

fn frame_opts(dataset: &str) -> ClientOptions {
    ClientOptions {
        wire: WireMode::Frame,
        dataset: Some(dataset.to_string()),
        ..Default::default()
    }
}

/// Everything a session observes: the metadata artifact bytes plus a
/// fixed schedule of SGE and WRE draws.
fn observe(c: &mut ServeClient) -> (Vec<u8>, Vec<(usize, Vec<usize>)>, Vec<Vec<usize>>) {
    let meta_bytes = binfmt::encode(&c.get_meta().unwrap());
    let sge = (0..4).map(|_| c.next_subset().unwrap()).collect();
    let wre = (0..3).map(|_| c.sample_wre(5).unwrap()).collect();
    (meta_bytes, sge, wre)
}

#[test]
fn pooled_streams_match_dedicated_connections_byte_for_byte() {
    let entries = vec![meta_for("mux-a", 31), meta_for("mux-b", 37)];
    let server = SubsetServer::bind_multi("127.0.0.1:0", entries, None, SEED).unwrap();
    let addr = server.addr().to_string();

    // fleet of six sessions, alternating between the two served entries
    let fleet: Vec<(String, &str)> = (0..6)
        .map(|i| (format!("trainer-{i}"), if i % 2 == 0 { "mux-a" } else { "mux-b" }))
        .collect();

    // dedicated pass: one socket per session
    let dedicated: Vec<_> = fleet
        .iter()
        .map(|(id, ds)| {
            let mut c = ServeClient::connect_with(&addr, id, frame_opts(ds)).unwrap();
            let seen = observe(&mut c);
            c.goodbye().unwrap();
            seen
        })
        .collect();

    // pooled pass: the same fleet multiplexed — all six fit one socket
    let pool = ConnectionPool::new(&addr);
    let mut pooled_clients: Vec<_> = fleet
        .iter()
        .map(|(id, ds)| ServeClient::connect_pooled(&pool, id, frame_opts(ds)).unwrap())
        .collect();
    assert_eq!(pool.connections(), 1, "six sessions share one pooled socket");

    for (c, (id, ds)) in pooled_clients.iter_mut().zip(&fleet) {
        assert_eq!(c.server_dataset(), *ds, "stream {id} routed to its entry");
    }
    let pooled: Vec<_> = pooled_clients.iter_mut().map(observe).collect();
    assert_eq!(
        pooled, dedicated,
        "pooled streams must replay the dedicated connections exactly",
    );
    // distinct entries really served distinct universes over one socket
    assert_ne!(pooled[0].0, pooled[1].0, "mux-a and mux-b metadata differ");

    for mut c in pooled_clients {
        c.goodbye().unwrap();
    }
    let stats = server.shutdown();
    assert_eq!(stats.subscribers, 0);
}

#[test]
fn a_full_connection_spills_to_a_second_socket() {
    let server =
        SubsetServer::bind("127.0.0.1:0", meta_for("spill", 41), None, SEED).unwrap();
    let addr = server.addr().to_string();
    let pool = ConnectionPool::new(&addr);

    // 31 leases fill the first socket (streams 1..=31; 0 is control) —
    // the 32nd must dial a second one
    let full = frame::MAX_STREAMS - 1;
    let mut sessions: Vec<ServeClient> = (0..full)
        .map(|i| {
            ServeClient::connect_pooled(&pool, &format!("s{i}"), frame_opts("spill"))
                .unwrap()
        })
        .collect();
    assert_eq!(pool.connections(), 1);
    sessions.push(
        ServeClient::connect_pooled(&pool, "one-more", frame_opts("spill")).unwrap(),
    );
    assert_eq!(pool.connections(), 2, "lease {} spills to a new socket", full + 1);

    // every session is live end-to-end across both sockets
    for s in &mut sessions {
        s.ping().unwrap();
    }

    // freeing a stream on the first socket lets the next lease reuse it
    sessions.remove(3).goodbye().unwrap();
    let mut replacement =
        ServeClient::connect_pooled(&pool, "reuse", frame_opts("spill")).unwrap();
    assert_eq!(pool.connections(), 2, "freed stream id is reused, no third socket");
    replacement.ping().unwrap();
    server.shutdown();
}

#[test]
fn pushes_fan_out_per_stream_identically_to_a_dedicated_subscriber() {
    let meta0 = meta_for("mux-push", 43);
    let server = SubsetServer::bind("127.0.0.1:0", meta0.clone(), None, SEED).unwrap();
    let addr = server.addr().to_string();

    let mut dedicated =
        ServeClient::connect_with(&addr, "solo", frame_opts("mux-push")).unwrap();
    dedicated.subscribe().unwrap();

    let pool = ConnectionPool::new(&addr);
    let mut pooled: Vec<ServeClient> = (0..3)
        .map(|i| {
            let mut c =
                ServeClient::connect_pooled(&pool, &format!("p{i}"), frame_opts("mux-push"))
                    .unwrap();
            c.subscribe().unwrap();
            c
        })
        .collect();
    assert_eq!(pool.connections(), 1, "all three subscribers share one socket");
    assert_eq!(server.stats().subscribers, 4, "subscribers gauge counts streams");

    let meta1 = meta_for("mux-push", 47);
    server.publish("mux-push", 2, meta1.clone()).unwrap();

    let want = dedicated
        .poll_push(5_000)
        .unwrap()
        .expect("dedicated subscriber sees the publish");
    assert_eq!(want.epoch, 2);
    assert_eq!(want.sge_subsets, meta1.sge_subsets);
    assert_eq!(want.fixed_dm, meta1.fixed_dm);

    // drain the pooled subscribers in reverse order: p2's poll reads p0's
    // and p1's interleaved burst frames first, which must be stashed for
    // their owners — not dropped, not misdelivered
    for c in pooled.iter_mut().rev() {
        let got = c
            .poll_push(5_000)
            .unwrap()
            .expect("every pooled stream sees the publish");
        assert_eq!(got, want, "pooled delivery is identical to dedicated");
    }
    // exactly once each, even after the cross-stream stashing
    for c in pooled.iter_mut() {
        assert!(c.poll_push(100).unwrap().is_none());
    }

    // per-stream GOODBYE: one session leaves, the shared socket and the
    // sibling subscriptions stay
    pooled.remove(0).goodbye().unwrap();
    assert_eq!(pool.connections(), 1);
    let meta2 = meta_for("mux-push", 53);
    server.publish("mux-push", 3, meta2.clone()).unwrap();
    for c in pooled.iter_mut() {
        let got = c.poll_push(5_000).unwrap().expect("survivors still follow");
        assert_eq!(got.epoch, 3);
        assert_eq!(got.sge_subsets, meta2.sge_subsets);
    }
    drop(pooled);
    drop(dedicated);
    server.shutdown();
}

#[test]
fn pooled_sibling_streams_keep_trace_ids_isolated() {
    // causal tracing over a shared socket: each pooled stream's control
    // reply must echo that stream's own trace id — interleaved siblings
    // never observe (or get handed) each other's ids
    let server =
        SubsetServer::bind("127.0.0.1:0", meta_for("mux-trace", 61), None, SEED).unwrap();
    let addr = server.addr().to_string();
    let pool = ConnectionPool::new(&addr);
    let mut a =
        ServeClient::connect_pooled(&pool, "trace-a", frame_opts("mux-trace")).unwrap();
    let mut b =
        ServeClient::connect_pooled(&pool, "trace-b", frame_opts("mux-trace")).unwrap();
    assert_eq!(pool.connections(), 1, "both sessions share one socket");
    assert!(a.trace_capable() && b.trace_capable(), "pooled HELLOs ack tracing");

    let (mut a_ids, mut b_ids) = (Vec::new(), Vec::new());
    for _ in 0..3 {
        a.ping().unwrap();
        let (ta, ea) = a.last_trace().unwrap();
        assert!(ea, "stream a's reply echoes stream a's id");
        b.ping().unwrap();
        let (tb, eb) = b.last_trace().unwrap();
        assert!(eb, "stream b's reply echoes stream b's id");
        a_ids.push(ta);
        b_ids.push(tb);
    }
    for t in &a_ids {
        assert!(!b_ids.contains(t), "sibling streams never share a trace id");
    }
    a.goodbye().unwrap();
    b.goodbye().unwrap();
    server.shutdown();
}

#[test]
fn stats_names_the_readiness_backend() {
    let server =
        SubsetServer::bind("127.0.0.1:0", meta_for("backend", 59), None, SEED).unwrap();
    let addr = server.addr().to_string();
    let mut c = ServeClient::connect(&addr, "probe").unwrap();
    let stats = c.stats().unwrap();
    let backend = stats.get("readiness").unwrap().as_str().unwrap().to_string();
    // Linux runs the epoll tier; anywhere else the poll/fallback tiers
    let expected: &[&str] = if cfg!(target_os = "linux") {
        &["epoll"]
    } else {
        &["poll", "fallback"]
    };
    assert!(
        expected.contains(&backend.as_str()),
        "unexpected readiness backend {backend:?}",
    );
    drop(c);
    server.shutdown();
}
