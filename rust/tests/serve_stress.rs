//! Stress tests for the event-loop subset server: one process, multiple
//! `(dataset, fraction)` entries, many concurrent clients mixing JSON-line
//! and binary-frame wire modes, with abrupt mid-stream disconnects thrown
//! in — asserting that
//!
//!   (a) every client's subset stream is exactly the stream the *inline*
//!       strategies (SGE cycle over `meta.sge_subsets`, `WreStrategy`
//!       draws from the documented per-client RNG) would produce from the
//!       shared metadata — the server adds transport, never transformation;
//!   (b) the wire format does not change stream content (JSON and frame
//!       clients with one id see one stream) and `GET_META` is
//!       byte-identical across modes (binfmt encoding compared);
//!   (c) other clients disconnecting mid-stream — abruptly, without a
//!       goodbye — perturb nothing;
//!   (d) connection slots are reclaimed: 100 connect/drop cycles leave no
//!       fd growth and no open-connection growth (the `ServeClient` drop
//!       goodbye + event-loop EOF sweep) — including cycles that
//!       SUBSCRIBE to push frames first, polite and abrupt alike, so a
//!       later epoch broadcast can never write into a reclaimed slot;
//!   (e) the telemetry surface holds under load: the extended `STATS`
//!       reply carries populated per-frame-type latency summaries with
//!       sane percentiles, the error counters are present (and zero on a
//!       healthy run), and every monotone counter is non-decreasing
//!       across successive snapshots.
//!
//! The `#[ignore]`d soak variants run the same topology much harder —
//! including the fleet-scale bar of **thousands of concurrent framed
//! connections** (fd-budget-aware: each in-process connection costs two
//! fds, so the target clamps to the soft `RLIMIT_NOFILE`; CI raises
//! `ulimit -n` and runs them in release mode via
//! `cargo test --release -- --ignored`).

use std::sync::Arc;

use milo::coordinator::Metadata;
use milo::data::DatasetId;
use milo::selection::WreStrategy;
use milo::serve::{
    client_start_cursor, client_stream_rng, ClientOptions, ServeClient, SubsetServer,
    WireMode,
};
use milo::store::binfmt;
use milo::testkit::synthetic_metadata;

const SEED: u64 = 42;
const WRE_K: usize = 24;

fn entries() -> Vec<Arc<Metadata>> {
    vec![
        Arc::new(synthetic_metadata(&DatasetId::Trec6Like.generate(SEED), 0.1)),
        Arc::new(synthetic_metadata(&DatasetId::RottenLike.generate(SEED), 0.3)),
    ]
}

/// The stream an inline consumer of the shared metadata would produce for
/// `client`: the SGE cycle starting at the client's staggered cursor, and
/// WRE draws from `WreStrategy` (the exact sampler `MiloStrategy` uses)
/// seeded with the documented per-client stream RNG.
fn inline_stream(
    meta: &Metadata,
    client: &str,
    rounds: usize,
) -> (Vec<(usize, Vec<usize>)>, Vec<Vec<usize>>) {
    let start = client_start_cursor(meta, client);
    let n = meta.sge_subsets.len();
    let sge: Vec<(usize, Vec<usize>)> = (0..rounds)
        .map(|i| {
            let idx = (start + i) % n;
            (idx, meta.sge_subsets[idx].clone())
        })
        .collect();
    let wre_inline = WreStrategy::new("inline", meta.wre_classes.clone());
    let mut rng = client_stream_rng(SEED, meta, client);
    let wre: Vec<Vec<usize>> =
        (0..rounds).map(|_| wre_inline.sample_k(WRE_K, &mut rng)).collect();
    (sge, wre)
}

/// Draw `rounds` alternating SGE/WRE pairs over the wire.
fn served_stream(
    addr: &str,
    client_id: &str,
    wire: WireMode,
    dataset: &str,
    rounds: usize,
) -> (Vec<(usize, Vec<usize>)>, Vec<Vec<usize>>) {
    let mut client = ServeClient::connect_with(
        addr,
        client_id,
        ClientOptions {
            wire,
            dataset: Some(dataset.to_string()),
            ..Default::default()
        },
    )
    .unwrap();
    let mut sge = Vec::with_capacity(rounds);
    let mut wre = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        sge.push(client.next_subset().unwrap());
        wre.push(client.sample_wre(WRE_K).unwrap());
    }
    (sge, wre)
}

fn run_mixed_fleet(n_clients: usize, rounds: usize) {
    let entries = entries();
    let server =
        SubsetServer::bind_multi("127.0.0.1:0", entries.clone(), None, SEED).unwrap();
    let addr = server.addr().to_string();

    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let addr = addr.clone();
            let entries = &entries;
            scope.spawn(move || {
                let meta = &entries[c % entries.len()];
                let wire = if c % 2 == 0 { WireMode::Json } else { WireMode::Frame };
                let id = format!("client-{c}");
                if c % 7 == 3 {
                    // abrupt mid-stream disconnect: a raw socket (not the
                    // polite ServeClient) draws a little and vanishes with
                    // a bare FIN, no GOODBYE — must perturb nobody
                    use std::io::{BufRead, BufReader, Write};
                    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
                    let mut reader = BufReader::new(raw.try_clone().unwrap());
                    let hello = format!(
                        "{{\"cmd\":\"HELLO\",\"client\":\"churn-{c}\",\"dataset\":{:?}}}\n",
                        meta.dataset,
                    );
                    raw.write_all(hello.as_bytes()).unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    assert!(line.contains("\"ok\":true"), "{line:?}");
                    raw.write_all(b"{\"cmd\":\"NEXT_SUBSET\"}\n").unwrap();
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    assert!(line.contains("\"ok\":true"), "{line:?}");
                    return; // raw drops here: mid-stream, no goodbye
                }
                let got = served_stream(&addr, &id, wire, &meta.dataset, rounds);
                let expect = inline_stream(meta, &id, rounds);
                assert_eq!(
                    got, expect,
                    "{id} ({wire:?}, {}) diverged from the inline strategy stream",
                    meta.dataset,
                );
            });
        }
    });

    // wire format does not change content: one id, both modes, same stream
    for meta in &entries {
        let a = served_stream(&addr, "bimodal", WireMode::Json, &meta.dataset, rounds);
        let b = served_stream(&addr, "bimodal", WireMode::Frame, &meta.dataset, rounds);
        assert_eq!(a, b, "wire mode changed the {} stream", meta.dataset);
    }

    // GET_META is byte-identical across modes and to the shared artifact
    for meta in &entries {
        let reference = binfmt::encode(meta);
        for wire in [WireMode::Json, WireMode::Frame] {
            let mut client = ServeClient::connect_with(
                &addr,
                "meta-probe",
                ClientOptions {
                    wire,
                    dataset: Some(meta.dataset.clone()),
                    fraction: Some(meta.fraction),
                    ..Default::default()
                },
            )
            .unwrap();
            let served = client.get_meta().unwrap();
            assert_eq!(
                binfmt::encode(&served),
                reference,
                "{} over {wire:?} is not byte-identical",
                meta.dataset,
            );
        }
    }

    let stats = server.stats();
    assert!(stats.connections >= n_clients as u64);
    assert!(stats.subsets_served > 0 && stats.wre_samples > 0);
    server.shutdown();
}

#[test]
fn fifty_mixed_clients_two_datasets_deterministic_streams() {
    run_mixed_fleet(50, 6);
}

/// The heavier version CI runs in release mode:
/// `cargo test --release --test serve_stress -- --ignored`.
#[test]
#[ignore = "soak test — run explicitly (CI runs it in release mode)"]
fn soak_fifty_clients_many_rounds() {
    for _ in 0..3 {
        run_mixed_fleet(50, 40);
    }
}

/// (e): STATS latency summaries populate under traffic and monotone
/// counters never decrease across snapshots.
#[test]
fn stats_reports_latency_summaries_and_monotone_counters() {
    let server = SubsetServer::bind_multi("127.0.0.1:0", entries(), None, SEED).unwrap();
    let addr = server.addr().to_string();

    let mut client = ServeClient::connect(&addr, "stats-probe").unwrap();
    let mut drawn = 0u64;
    let mut prev = server.stats();
    for round in 1..=5u64 {
        for _ in 0..4 {
            client.next_subset().unwrap();
            client.sample_wre(WRE_K).unwrap();
            drawn += 1;
        }

        // monotone counters never decrease between snapshots
        let now = server.stats();
        assert!(now.connections >= prev.connections, "connections decreased");
        assert!(now.requests > prev.requests, "requests did not advance");
        assert!(now.subsets_served >= prev.subsets_served + 4);
        assert!(now.wre_samples >= prev.wre_samples + 4);
        assert!(now.bytes_rx > prev.bytes_rx, "bytes_rx did not advance");
        assert!(now.bytes_tx > prev.bytes_tx, "bytes_tx did not advance");
        assert!(now.goodbyes >= prev.goodbyes);
        prev = now;

        let stats = client.stats().unwrap();
        // the error counters are surfaced, and a healthy run has none
        assert_eq!(stats.get("accept_errors").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(stats.get("wbuf_teardowns").unwrap().as_f64().unwrap(), 0.0);

        // per-frame-type latency summaries are populated with sane shapes
        let metrics = stats.get("metrics").unwrap();
        let next = metrics.get("serve.request_latency_ns.next_subset").unwrap();
        let count = next.get("count").unwrap().as_f64().unwrap();
        assert!(
            count >= drawn as f64,
            "round {round}: NEXT_SUBSET latency count {count} < {drawn} draws"
        );
        let p50 = next.get("p50_us").unwrap().as_f64().unwrap();
        let p99 = next.get("p99_us").unwrap().as_f64().unwrap();
        let max = next.get("max_us").unwrap().as_f64().unwrap();
        assert!(p50 > 0.0, "round {round}: p50 must be positive, got {p50}");
        assert!(p99 >= p50, "round {round}: p99 {p99} below p50 {p50}");
        assert!(max >= p50, "round {round}: max {max} below p50 {p50}");
        let wre = metrics.get("serve.request_latency_ns.sample_wre").unwrap();
        assert!(wre.get("count").unwrap().as_f64().unwrap() >= drawn as f64);
        // STATS itself is instrumented too — the in-flight request records
        // *after* its reply is built, so this snapshot sees the prior ones
        let st = metrics.get("serve.request_latency_ns.stats").unwrap();
        assert!(st.get("count").unwrap().as_f64().unwrap() >= (round - 1) as f64);
    }
    drop(client);
    server.shutdown();
}

#[cfg(target_os = "linux")]
fn open_fd_count() -> Option<usize> {
    std::fs::read_dir("/proc/self/fd").ok().map(|d| d.count())
}

#[cfg(not(target_os = "linux"))]
fn open_fd_count() -> Option<usize> {
    None
}

#[test]
fn hundred_connect_drop_cycles_leak_no_slots_and_no_fds() {
    let server = SubsetServer::bind_multi("127.0.0.1:0", entries(), None, SEED).unwrap();
    let addr = server.addr().to_string();
    const CYCLES: u64 = 100;

    // settle a baseline after one warmup connection
    {
        let mut warm = ServeClient::connect(&addr, "warmup").unwrap();
        warm.ping().unwrap();
        warm.goodbye().unwrap();
    }
    wait_until(|| server.stats().open_connections == 0, "warmup close");
    let fd_baseline = open_fd_count();

    for c in 0..CYCLES {
        let wire = if c % 2 == 0 { WireMode::Json } else { WireMode::Frame };
        let mut client = ServeClient::connect_with(
            &addr,
            &format!("cycle-{c}"),
            ClientOptions { wire, ..Default::default() },
        )
        .unwrap();
        let _ = client.next_subset().unwrap();
        // frame-wire cycles churn the subscriber list too: subscribe,
        // then leave either politely (GOODBYE via Drop) or abruptly
        // (bare FIN) — both must clear the subscription with the slot
        if wire == WireMode::Frame {
            client.subscribe().unwrap();
            if c % 4 == 1 {
                client.abandon();
            }
        }
        drop(client); // Drop sends the goodbye (unless abandoned)
    }

    // every slot must be reclaimed (goodbye fast path or EOF sweep),
    // and no stale subscription may outlive its connection
    wait_until(
        || server.stats().open_connections == 0,
        "open_connections back to 0 after 100 connect/drop cycles",
    );
    assert_eq!(
        server.stats().subscribers,
        0,
        "subscriber list must drain with the connections"
    );
    let stats = server.stats();
    assert_eq!(stats.connections, CYCLES + 1, "accepted every cycle");
    // every 4th cycle abandoned without a goodbye; the rest must have one
    let polite = CYCLES - CYCLES / 4;
    assert!(
        stats.goodbyes >= polite,
        "drop must send goodbyes (got {} of {polite})",
        stats.goodbyes,
    );
    // and the process-level view agrees: no fd growth. Other tests in
    // this binary run concurrently and own fds too, so wait for the
    // count to settle back rather than asserting an instantaneous value.
    if let Some(base) = fd_baseline {
        wait_until(
            || open_fd_count().map_or(true, |now| now <= base + 2),
            "process fd count to settle back to the pre-cycle baseline",
        );
    }
    server.shutdown();
}

fn wait_until(cond: impl Fn() -> bool, what: &str) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while !cond() {
        assert!(std::time::Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
}

// ---------------------------------------------------------------------------
// Fleet-scale connection soak + buffer high-water reclamation
// ---------------------------------------------------------------------------

/// Soft `RLIMIT_NOFILE` from `/proc/self/limits` (None off Linux).
fn fd_soft_limit() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/limits").ok()?;
    let line = text.lines().find(|l| l.starts_with("Max open files"))?;
    line.split_whitespace().nth(3)?.parse().ok()
}

/// Hold `target` concurrent JSON-line connections open at once (clamped
/// to the fd budget: two fds per in-process connection, 100 reserved for
/// the rest of the test binary), ping a sample mid-soak, then close all
/// and assert every gauge returns to zero. Returns the connection count
/// actually soaked.
fn run_connection_soak(target: usize) -> usize {
    use std::io::{BufRead, BufReader, Write};

    let entries = entries();
    let dataset = entries[0].dataset.clone();
    let server = SubsetServer::bind_multi("127.0.0.1:0", entries, None, SEED).unwrap();
    let addr = server.addr().to_string();

    let budget = fd_soft_limit().map_or(target, |soft| {
        (soft.saturating_sub(100) / 2) as usize
    });
    let n = target.min(budget).max(1);

    let mut conns: Vec<(std::net::TcpStream, BufReader<std::net::TcpStream>)> =
        Vec::with_capacity(n);
    let mut line = String::new();
    for c in 0..n {
        let mut sock = std::net::TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let hello =
            format!("{{\"cmd\":\"HELLO\",\"client\":\"soak-{c}\",\"dataset\":{dataset:?}}}\n");
        sock.write_all(hello.as_bytes()).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "soak-{c} HELLO: {line:?}");
        conns.push((sock, reader));
    }
    assert_eq!(server.stats().open_connections, n as u64, "all {n} conns held open");

    // a synchronized ping wave across the whole fleet: every connection
    // writes before any reads, so one tick sees thousands of ready
    // sockets at once — readiness, read quanta, and the write round-robin
    // all under fire
    for (sock, _) in conns.iter_mut() {
        sock.write_all(b"{\"cmd\":\"PING\"}\n").unwrap();
    }
    for (c, (_, reader)) in conns.iter_mut().enumerate() {
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "soak-{c} ping: {line:?}");
    }

    // subset service still exact at full occupancy (sampled)
    for c in (0..n).step_by((n / 16).max(1)) {
        let (sock, reader) = &mut conns[c];
        sock.write_all(b"{\"cmd\":\"NEXT_SUBSET\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"subset\""), "soak-{c} subset: {line:?}");
    }

    drop(conns); // bare FINs, fleet-wide at once
    wait_until(
        || server.stats().open_connections == 0,
        "fleet-wide FIN sweep back to zero open connections",
    );
    let end = server.shutdown();
    assert_eq!(end.open_connections, 0);
    assert_eq!(end.subscribers, 0);
    assert_eq!(end.buffer_bytes, 0, "no buffer capacity outlives the fleet");
    assert!(end.connections >= n as u64);
    n
}

/// Smoke tier: hundreds of concurrent connections inside the default
/// 1024-fd budget, every run.
#[test]
fn smoke_hundreds_of_concurrent_connections() {
    let n = run_connection_soak(300);
    assert!(n >= 64, "fd budget too tight to smoke the soak path ({n})");
}

/// Full tier, CI-only: the fleet-scale bar from the ROADMAP — thousands
/// of concurrent framed connections on one event-loop thread. CI raises
/// `ulimit -n` first; on a default 1024-fd shell this clamps itself.
#[test]
#[ignore = "fleet-scale soak — CI raises ulimit -n and runs it in release mode"]
fn soak_thousands_of_concurrent_connections() {
    let n = run_connection_soak(2_000);
    // on a raised-ulimit runner (CI does `ulimit -n 16384`) the full bar
    // must actually be met — the clamp is for default shells, not CI
    if fd_soft_limit().map_or(false, |soft| soft >= 4_200) {
        assert_eq!(n, 2_000, "fd budget allowed the full bar but only {n} soaked");
    }
}

/// Buffer high-water bugfix (satellite): a burst that balloons a
/// connection's outbound buffer must not pin that allocation for the
/// connection's lifetime. After the backlog flushes, capacity above the
/// keep threshold is returned, observable on the `serve.buffer_bytes`
/// gauge.
#[test]
fn burst_buffer_capacity_is_returned_after_flush() {
    use std::io::{BufRead, BufReader, Write};

    const BUF_KEEP_BYTES: u64 = 64 << 10; // mirrors serve::BUF_KEEP_BYTES

    let entries = entries();
    let dataset = entries[0].dataset.clone();
    let server = SubsetServer::bind_multi("127.0.0.1:0", entries, None, SEED).unwrap();
    let addr = server.addr().to_string();

    // a raw framed socket so responses can pile up server-side: HELLO,
    // confirm frame mode, then pipeline GET_METAs without reading
    let mut sock = std::net::TcpStream::connect(&addr).unwrap();
    sock.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let hello = format!(
        "{{\"cmd\":\"HELLO\",\"client\":\"burst\",\"wire\":\"frame\",\"dataset\":{dataset:?}}}\n",
    );
    sock.write_all(hello.as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"wire\":\"frame\""), "{line:?}");

    fn read_frame(reader: &mut std::io::BufReader<std::net::TcpStream>) -> usize {
        use std::io::Read;
        let mut header = [0u8; milo::serve::frame::HEADER_LEN];
        reader.read_exact(&mut header).unwrap();
        let (len, _, _) = milo::serve::frame::parse_header(&header).unwrap();
        let mut payload = vec![0u8; len];
        reader.read_exact(&mut payload).unwrap();
        milo::serve::frame::HEADER_LEN + len
    }

    // size one response, then pipeline enough that the backlog dwarfs
    // whatever the kernel's socket buffers can absorb — the excess must
    // land in the server's wbuf
    let req = milo::serve::Frame::Json("{\"cmd\":\"GET_META\"}".to_string()).encode();
    sock.write_all(&req).unwrap();
    let one = read_frame(&mut reader);
    let pipeline = (24 * 1024 * 1024 / one).clamp(64, 4096);
    for _ in 0..pipeline {
        sock.write_all(&req).unwrap();
    }
    // the backlog builds real capacity: well past the keep threshold
    wait_until(
        || server.stats().buffer_bytes > 4 * BUF_KEEP_BYTES,
        "pipelined GET_META backlog to balloon the connection buffers",
    );

    // drain everything client-side so the server finishes its flush
    for _ in 0..pipeline {
        read_frame(&mut reader);
    }

    // the fix: post-flush, capacity above the keep threshold is released
    // (rbuf + wbuf + decoder each keep at most BUF_KEEP_BYTES)
    wait_until(
        || server.stats().buffer_bytes <= 4 * BUF_KEEP_BYTES,
        "burst capacity to be returned after the flush",
    );
    assert!(server.stats().buffer_bytes > 0, "a live connection holds some buffer");

    drop(sock);
    drop(reader);
    wait_until(|| server.stats().open_connections == 0, "burst conn swept");
    let end = server.shutdown();
    assert_eq!(end.buffer_bytes, 0, "gauge drains with the connection");
}
