//! Property tests for the submodular machinery (testkit-driven seed
//! sweeps; proptest is unavailable offline).

use milo::submod::{
    greedy_maximize, sample_importance, weighted_sample_without_replacement,
    functions::brute_force_value, GreedyMode, SetFunctionKind,
};
use milo::testkit::{check_cases, clustered_kernel, random_kernel};
use milo::util::rng::Rng;

const KINDS: [SetFunctionKind; 4] = [
    SetFunctionKind::FacilityLocation,
    SetFunctionKind::GraphCut { lambda: 0.4 },
    SetFunctionKind::DisparitySum,
    SetFunctionKind::DisparityMin,
];

#[test]
fn prop_incremental_value_matches_brute_force() {
    check_cases(100, 20, |seed| {
        let n = 8 + (seed % 12) as usize;
        let s = random_kernel(n, seed);
        let mut rng = Rng::new(seed ^ 1);
        for kind in KINDS {
            let mut f = kind.build(&s);
            let k = 1 + rng.below(n.min(6));
            let trace = greedy_maximize(f.as_mut(), k, GreedyMode::Naive, kind.lazy_safe(), &mut rng);
            let brute = brute_force_value(kind, &s, &trace.selected);
            let inc = f.value();
            assert!(
                (inc - brute).abs() < 1e-3 * (1.0 + brute.abs()),
                "{kind:?} n={n} k={k}: incremental {inc} vs brute {brute}"
            );
        }
    });
}

#[test]
fn prop_submodular_gains_never_increase_along_greedy() {
    check_cases(200, 20, |seed| {
        let n = 10 + (seed % 15) as usize;
        let s = random_kernel(n, seed);
        let mut rng = Rng::new(seed);
        for kind in [SetFunctionKind::FacilityLocation, SetFunctionKind::GraphCut { lambda: 0.4 }] {
            let mut f = kind.build(&s);
            let trace =
                greedy_maximize(f.as_mut(), n.min(8), GreedyMode::Naive, true, &mut rng);
            for w in trace.gains.windows(2) {
                assert!(
                    w[0] >= w[1] - 1e-4,
                    "{kind:?}: gains increased {:?}",
                    trace.gains
                );
            }
        }
    });
}

#[test]
fn prop_lazy_matches_naive_everywhere() {
    check_cases(300, 15, |seed| {
        let n = 12 + (seed % 20) as usize;
        let s = random_kernel(n, seed);
        for kind in KINDS {
            if !kind.lazy_safe() {
                continue;
            }
            let mut rng = Rng::new(0);
            let mut f1 = kind.build(&s);
            let t1 = greedy_maximize(f1.as_mut(), 6.min(n), GreedyMode::Naive, true, &mut rng);
            let mut f2 = kind.build(&s);
            let t2 = greedy_maximize(f2.as_mut(), 6.min(n), GreedyMode::Lazy, true, &mut rng);
            // values must agree even if tie-breaking differs
            let v1 = brute_force_value(kind, &s, &t1.selected);
            let v2 = brute_force_value(kind, &s, &t2.selected);
            assert!(
                (v1 - v2).abs() < 1e-3 * (1.0 + v1.abs()),
                "{kind:?} seed {seed}: naive {v1} vs lazy {v2}"
            );
        }
    });
}

#[test]
fn prop_greedy_covers_clusters_facility_location() {
    // FL with k = #clusters must take one element per cluster
    check_cases(400, 10, |seed| {
        let clusters = 3 + (seed % 3) as usize;
        let n = clusters * 8;
        let (s, assign) = clustered_kernel(n, clusters, 0.9, 0.15, seed);
        let mut rng = Rng::new(seed);
        let mut f = SetFunctionKind::FacilityLocation.build(&s);
        let t = greedy_maximize(f.as_mut(), clusters, GreedyMode::Naive, true, &mut rng);
        let covered: std::collections::HashSet<usize> =
            t.selected.iter().map(|&i| assign[i]).collect();
        assert_eq!(covered.len(), clusters, "FL missed clusters: {:?}", t.selected);
    });
}

#[test]
fn prop_disparity_min_spreads_across_clusters() {
    check_cases(500, 10, |seed| {
        let clusters = 4;
        let n = clusters * 6;
        let (s, assign) = clustered_kernel(n, clusters, 0.92, 0.2, seed);
        let mut rng = Rng::new(seed);
        let mut f = SetFunctionKind::DisparityMin.build(&s);
        let t = greedy_maximize(f.as_mut(), clusters, GreedyMode::Naive, false, &mut rng);
        let covered: std::collections::HashSet<usize> =
            t.selected.iter().map(|&i| assign[i]).collect();
        assert_eq!(covered.len(), clusters, "DM clumped: {:?}", t.selected);
    });
}

#[test]
fn prop_stochastic_greedy_within_factor_of_full_greedy() {
    check_cases(600, 8, |seed| {
        let n = 60;
        let k = 10;
        let s = random_kernel(n, seed);
        let kind = SetFunctionKind::FacilityLocation;
        let mut rng = Rng::new(seed);
        let mut f_full = kind.build(&s);
        let full = greedy_maximize(f_full.as_mut(), k, GreedyMode::Naive, true, &mut rng);
        let v_full = brute_force_value(kind, &s, &full.selected);
        let mut f_sg = kind.build(&s);
        let sg = greedy_maximize(
            f_sg.as_mut(),
            k,
            GreedyMode::Stochastic { epsilon: 0.01 },
            true,
            &mut rng,
        );
        let v_sg = brute_force_value(kind, &s, &sg.selected);
        assert!(
            v_sg >= 0.85 * v_full,
            "stochastic too weak: {v_sg} vs {v_full} (seed {seed})"
        );
    });
}

#[test]
fn prop_sample_importance_is_permutation_of_gains() {
    check_cases(700, 10, |seed| {
        let n = 20 + (seed % 10) as usize;
        let s = random_kernel(n, seed);
        for kind in KINDS {
            let mut f = kind.build(&s);
            let g = sample_importance(f.as_mut(), kind.lazy_safe());
            assert_eq!(g.len(), n);
            // every element got a score; for representation functions all
            // finite
            assert!(g.iter().all(|v| v.is_finite()), "{kind:?}: {g:?}");
        }
    });
}

#[test]
fn prop_weighted_sampling_marginals_order_by_weight() {
    // items with larger weight appear at least as often (statistically)
    let mut rng = Rng::new(42);
    let w: Vec<f64> = vec![0.5, 1.0, 2.0, 4.0, 8.0];
    let mut counts = [0usize; 5];
    for _ in 0..4000 {
        for i in weighted_sample_without_replacement(&w, 2, &mut rng) {
            counts[i] += 1;
        }
    }
    for i in 0..4 {
        assert!(
            counts[i] < counts[i + 1] + 150,
            "marginals not ordered: {counts:?}"
        );
    }
}
