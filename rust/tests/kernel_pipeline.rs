//! Property suite for the overlapped kernel-construction pipeline.
//!
//! The contract under test: a [`KernelSchedule`] is **schedule-only** —
//! `strip_rows` and `depth` change when work happens, never any stored
//! value — so every pipelined build must be *bit-identical* to the
//! serial (`depth = 1`) reference, for every metric, dense and sparse
//! layouts, and both backends. [`SparseKernel`]'s derived `PartialEq`
//! compares the exact CSR arrays, so every assertion here is
//! `assert_eq!`, not approximate.
//!
//! Also covered: panic containment (a producer or consumer panic
//! surfaces as `Err` from [`run_pipeline`], never a deadlock or a
//! poisoned build) and the degenerate schedules (`depth = 1`, one
//! strip) matching the threaded ones.

use milo::kernel::pipeline::run_pipeline;
use milo::kernel::sparse::{sparse_native, sparse_native_scheduled, sparse_pjrt_scheduled};
use milo::kernel::{
    build_class_kernels_scheduled, ClassSim, KernelSchedule, SimMetric, SimilarityBackend,
};
use milo::testkit::{artifacts_or_skip, check_cases, random_embeddings};
use milo::util::rng::Rng;

const METRICS: [SimMetric; 3] = [SimMetric::Cosine, SimMetric::Dot, SimMetric::Rbf { kw: 0.5 }];

/// Schedules to sweep against the serial reference: double buffering,
/// deep pipelines, odd strip heights (non-dividing, strip = 1, strip
/// larger than n).
fn schedules() -> Vec<KernelSchedule> {
    vec![
        KernelSchedule::default(),
        KernelSchedule { strip_rows: None, depth: 4 },
        KernelSchedule { strip_rows: Some(1), depth: 2 },
        KernelSchedule { strip_rows: Some(7), depth: 3 },
        KernelSchedule { strip_rows: Some(64), depth: 2 },
        KernelSchedule { strip_rows: Some(1 << 20), depth: 8 },
    ]
}

#[test]
fn native_sparse_pipelined_is_bit_identical_to_serial() {
    check_cases(xk_seed(), 6, |seed| {
        let mut rng = Rng::new(seed);
        let n = 16 + (rng.next_u64() % 60) as usize;
        let e = 4 + (rng.next_u64() % 12) as usize;
        let knn = 1 + (rng.next_u64() % 9) as usize;
        let z = random_embeddings(n, e, seed);
        for metric in METRICS {
            let (reference, _) =
                sparse_native_scheduled(&z, metric, knn, &KernelSchedule::serial()).unwrap();
            // the convenience wrapper is the default schedule
            assert_eq!(sparse_native(&z, metric, knn), reference);
            for sched in schedules() {
                let (got, stats) = sparse_native_scheduled(&z, metric, knn, &sched).unwrap();
                assert_eq!(got, reference, "metric {metric:?} sched {sched:?}");
                assert!(stats.stall_secs <= stats.wall_secs + 1e-3);
            }
        }
    });
}

#[test]
fn class_kernel_builds_match_across_schedules() {
    check_cases(xk_seed() ^ 1, 4, |seed| {
        let mut rng = Rng::new(seed);
        let classes = 2 + (rng.next_u64() % 3) as usize;
        let n = classes * (10 + (rng.next_u64() % 20) as usize);
        let z = random_embeddings(n, 6, seed);
        let partition: Vec<Vec<usize>> = (0..classes)
            .map(|c| (0..n).filter(|i| i % classes == c).collect())
            .collect();
        for metric in METRICS {
            for knn in [None, Some(5)] {
                let reference = build_class_kernels_scheduled(
                    None,
                    &z,
                    &partition,
                    metric,
                    SimilarityBackend::Native,
                    knn,
                    &KernelSchedule::serial(),
                )
                .unwrap();
                for sched in schedules() {
                    let got = build_class_kernels_scheduled(
                        None,
                        &z,
                        &partition,
                        metric,
                        SimilarityBackend::Native,
                        knn,
                        &sched,
                    )
                    .unwrap();
                    assert_eq!(got.per_class.len(), reference.per_class.len());
                    for (g, r) in got.per_class.iter().zip(&reference.per_class) {
                        assert_eq!(g.indices, r.indices);
                        match (&g.sim, &r.sim) {
                            (ClassSim::Dense(a), ClassSim::Dense(b)) => {
                                assert_eq!(a.data(), b.data(), "dense {metric:?}")
                            }
                            (ClassSim::Sparse(a), ClassSim::Sparse(b)) => {
                                assert_eq!(a, b, "sparse {metric:?} {sched:?}")
                            }
                            _ => panic!("layout changed with the schedule"),
                        }
                    }
                }
            }
        }
    });
}

/// PJRT path: serial vs pipelined strips, and — when `topk_*` artifacts
/// are present — the on-device candidate cut vs the host-side reduction
/// (forced by asking for more neighbours than the artifact's `K`).
#[test]
fn pjrt_sparse_pipelined_is_bit_identical_to_serial() {
    let Some(rt) = artifacts_or_skip() else { return };
    check_cases(xk_seed() ^ 2, 3, |seed| {
        let mut rng = Rng::new(seed);
        let n = 40 + (rng.next_u64() % 80) as usize;
        let z = random_embeddings(n, 32, seed);
        let serial = KernelSchedule::serial();
        let deep = KernelSchedule { strip_rows: None, depth: 3 };
        for metric in METRICS {
            for knn in [3, 9] {
                let (reference, _) = sparse_pjrt_scheduled(&rt, &z, metric, knn, &serial).unwrap();
                let (got, _) =
                    sparse_pjrt_scheduled(&rt, &z, metric, knn, &KernelSchedule::default())
                        .unwrap();
                assert_eq!(got, reference, "metric {metric:?} knn {knn}");
                // host fallback (knn > K disables the device cut) must
                // agree wherever both paths can run
                let base = match metric {
                    SimMetric::Cosine => "cosine",
                    SimMetric::Dot => "dot",
                    SimMetric::Rbf { .. } => "rbf",
                };
                let device_k = rt
                    .manifest()
                    .artifacts
                    .get(&format!("topk_{base}_e32"))
                    .and_then(|a| a.k);
                if let Some(k) = device_k {
                    let hk = (k + 1).min(n);
                    let (host, _) = sparse_pjrt_scheduled(&rt, &z, metric, hk, &serial).unwrap();
                    let (piped, _) = sparse_pjrt_scheduled(&rt, &z, metric, hk, &deep).unwrap();
                    assert_eq!(piped, host, "host-path metric {metric:?}");
                }
            }
        }
    });
}

#[test]
fn producer_panic_surfaces_as_err_not_deadlock() {
    for depth in [1, 2, 4] {
        let r = run_pipeline(
            16,
            depth,
            Vec::new(),
            |t| {
                if t == 5 {
                    panic!("injected producer failure");
                }
                Ok(vec![t as f32; 8])
            },
            |acc: &mut Vec<f32>, _, strip: Vec<f32>| acc.extend(strip),
        );
        let err = format!("{:#}", r.unwrap_err());
        assert!(err.contains("producer"), "depth {depth}: {err}");
        assert!(err.contains("injected producer failure"), "depth {depth}: {err}");
    }
}

#[test]
fn consumer_panic_surfaces_as_err_not_deadlock() {
    let r = run_pipeline(
        128,
        2,
        (),
        |t| Ok(t),
        |_: &mut (), t, _| {
            if t == 3 {
                panic!("injected consumer failure");
            }
        },
    );
    let err = format!("{:#}", r.unwrap_err());
    assert!(err.contains("consumer"), "{err}");
}

#[test]
fn depth_one_consumes_inline_in_order() {
    let (order, stats) = run_pipeline(
        9,
        1,
        Vec::new(),
        |t| Ok(t),
        |order: &mut Vec<usize>, t, v| {
            assert_eq!(t, v);
            order.push(t);
        },
    )
    .unwrap();
    assert_eq!(order, (0..9).collect::<Vec<_>>());
    assert_eq!(stats.strips, 9);
    assert_eq!(stats.stall_secs, 0.0);
}

fn xk_seed() -> u64 {
    0x6b65726e // "kern"
}
