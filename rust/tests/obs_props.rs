//! Property tests for the observability layer: the histogram bucket
//! scheme (recorded values stay within their reported bucket bounds, for
//! random values across every magnitude), percentile bracketing under
//! merge (a merged histogram's quantiles never leave the envelope of its
//! inputs' quantiles — the property that makes per-thread recording +
//! merge-on-exit sound), saturation behaviour at the value cap, and a
//! golden test pinning the text exposition format byte-for-byte.

use milo::obs::hist::{bucket_bounds, bucket_index, MAX_VALUE, N_BUCKETS};
use milo::obs::{Histogram, MetricsRegistry};
use milo::util::rng::Rng;

/// Random values spanning every magnitude (uniform in log2 space).
fn random_values(seed: u64, n: usize) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let bits = (rng.next_u64() % 41) as u32; // 0..=40 bits of magnitude
            if bits == 0 {
                rng.next_u64() % 2
            } else {
                (1u64 << (bits - 1)) + rng.next_u64() % (1u64 << (bits - 1))
            }
        })
        .collect()
}

#[test]
fn recorded_values_stay_within_their_bucket_bounds() {
    for v in random_values(0xB0C4E7, 4000) {
        let i = bucket_index(v);
        assert!(i < N_BUCKETS, "bucket_index({v}) = {i} out of range");
        let (lo, hi) = bucket_bounds(i);
        assert!(
            lo <= v && v <= hi,
            "value {v} landed in bucket {i} with bounds [{lo}, {hi}]"
        );
    }
    // and the recording path agrees with the indexing function: a single
    // recorded value bumps exactly the bucket whose bounds contain it
    for v in random_values(0x5EED, 200) {
        let h = Histogram::new();
        h.record(v);
        let s = h.snapshot();
        let hit: Vec<usize> = s
            .counts()
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(hit.len(), 1, "recording one value must hit one bucket");
        let (lo, hi) = bucket_bounds(hit[0]);
        assert!(lo <= v && v <= hi, "{v} recorded outside [{lo}, {hi}]");
        assert_eq!(s.count(), 1);
        assert_eq!(s.sum(), v);
        assert_eq!(s.max(), v);
    }
}

#[test]
fn merged_percentiles_are_bracketed_by_the_inputs() {
    for seed in 0..20u64 {
        let a = Histogram::new();
        let b = Histogram::new();
        let na = 1 + (seed as usize * 37) % 400;
        let nb = 1 + (seed as usize * 53) % 400;
        for v in random_values(seed * 2 + 1, na) {
            a.record(v);
        }
        for v in random_values(seed * 2 + 2, nb) {
            b.record(v);
        }
        let m = Histogram::new();
        m.merge(&a);
        m.merge(&b);
        let (sa, sb, sm) = (a.snapshot(), b.snapshot(), m.snapshot());
        assert_eq!(sm.count(), sa.count() + sb.count());
        assert_eq!(sm.sum(), sa.sum() + sb.sum());
        assert_eq!(sm.max(), sa.max().max(sb.max()));
        for q in [0.0, 0.25, 0.5, 0.75, 0.95, 0.99, 1.0] {
            let (pa, pb, pm) =
                (sa.percentile(q), sb.percentile(q), sm.percentile(q));
            assert!(
                pa.min(pb) <= pm && pm <= pa.max(pb),
                "seed {seed} q={q}: merged percentile {pm} outside \
                 [{}, {}]",
                pa.min(pb),
                pa.max(pb),
            );
        }
    }
}

#[test]
fn values_above_the_cap_saturate_and_are_counted() {
    let h = Histogram::new();
    h.record(MAX_VALUE);
    h.record(MAX_VALUE + 1);
    h.record(u64::MAX);
    let s = h.snapshot();
    assert_eq!(s.count(), 3);
    assert_eq!(s.saturated(), 2, "two values were above the cap");
    // all three land in the top bucket; percentiles answer with the cap
    assert_eq!(s.percentile(1.0), MAX_VALUE);
    assert_eq!(s.max(), MAX_VALUE, "max is clamped to the representable cap");
}

#[test]
fn empty_histogram_percentiles_are_zero() {
    // pinned: a histogram nobody recorded into answers 0 for every
    // quantile (not a bucket bound, not NaN) — scrapes and STATS render
    // a quiet server as zeros, never garbage
    let s = Histogram::new().snapshot();
    assert_eq!(s.count(), 0);
    for q in [0.0, 0.5, 0.99, 1.0, -3.0, 7.0] {
        assert_eq!(s.percentile(q), 0, "empty histogram, q={q}");
    }
    assert_eq!(s.max(), 0);
    assert_eq!(s.sum(), 0);
}

#[test]
fn gauge_never_wraps_at_either_end() {
    // pinned: gauges saturate — a decrement below zero floors at 0 and
    // an increment at the cap pegs at u64::MAX (see the unit tests in
    // milo::obs for the full matrix; this pins the public behaviour)
    let reg = MetricsRegistry::new();
    let g = reg.gauge("props.sat");
    g.dec(1);
    assert_eq!(g.get(), 0, "underflow floors at zero");
    g.set(u64::MAX);
    g.add(u64::MAX);
    assert_eq!(g.get(), u64::MAX, "overflow pegs at the cap");
}

#[test]
fn exposition_text_is_stable() {
    let reg = MetricsRegistry::new();
    let hits = reg.counter("store.hits");
    hits.add(3);
    let open = reg.gauge("serve.open_connections");
    open.set(2);
    let lat = reg.histogram("serve.request_latency_ns.ping");
    for v in [1u64, 2, 3, 4] {
        lat.record(v);
    }
    let mut out = String::new();
    reg.render_text(&mut out);
    // golden: names sanitized to [A-Za-z0-9_] under a `milo_` prefix,
    // BTreeMap (sorted) order, integer values, histograms as summaries
    let expect = "\
# TYPE milo_serve_open_connections gauge
milo_serve_open_connections 2
# TYPE milo_serve_request_latency_ns_ping summary
milo_serve_request_latency_ns_ping{quantile=\"0.5\"} 2
milo_serve_request_latency_ns_ping{quantile=\"0.95\"} 4
milo_serve_request_latency_ns_ping{quantile=\"0.99\"} 4
milo_serve_request_latency_ns_ping_sum 10
milo_serve_request_latency_ns_ping_count 4
# TYPE milo_store_hits counter
milo_store_hits 3
";
    assert_eq!(out, expect);
}
