//! Bit-identity property suite for the continual-arrival subsystem.
//!
//! The contract under test: N incremental appends plus re-selection must
//! be **byte-identical** to a from-scratch batch build over the
//! concatenated dataset — the maintained class kernels (dense and sparse
//! top-`knn`), the SGE subset pool, the WRE Taylor-softmax distribution,
//! and the fixed disparity-min subset. The continual path *is* the batch
//! recipe with revision-keyed caches bolted on, so every assertion here
//! is exact `assert_eq!` — any drift is a bug, not float noise.
//!
//! Coverage: every [`SimMetric`] × dense/sparse kernel layout for the
//! kernel maintenance, and every [`SetFunctionKind`] (in both the SGE
//! and the WRE/fixed role) for the re-selection, plus the replay-buffer
//! workload's mid-stream `set_fraction` resizing.

use milo::continual::{ContinualOptions, ContinualSelector};
use milo::coordinator::{
    fixed_subset_from_kernels, sge_subsets_from_kernels, wre_distribution_from_kernels,
    Metadata,
};
use milo::kernel::{
    build_class_kernels, ClassKernels, ClassSim, SimMetric, SimilarityBackend,
};
use milo::submod::SetFunctionKind;
use milo::tensor::Matrix;
use milo::testkit::random_embeddings;
use milo::util::rng::Rng;

const CLASSES: usize = 4;
const DIM: usize = 7;
const N: usize = 72;
/// Uneven arrival waves (including a single-point wave) — each wave is
/// one `advance_epoch`, so later epochs exercise the cache/dirty paths.
const WAVES: &[(usize, usize)] = &[(0, 17), (17, 18), (18, 49), (49, 72)];

/// The batch-side class partition matching `arrive(i % CLASSES, row i)`.
fn striped_partition(n: usize, classes: usize) -> Vec<Vec<usize>> {
    let mut parts = vec![Vec::new(); classes];
    for i in 0..n {
        parts[i % classes].push(i);
    }
    parts
}

/// Feed `z` through the arrival waves (row `i` ↦ class `i % CLASSES`),
/// advancing one epoch per wave; returns the selector and the last
/// epoch's metadata.
fn stream(z: &Matrix, opts: ContinualOptions) -> (ContinualSelector, Metadata) {
    let mut sel = ContinualSelector::new(opts);
    let mut last = None;
    for &(lo, hi) in WAVES {
        for i in lo..hi {
            assert_eq!(sel.arrive(i % CLASSES, z.row(i)).unwrap(), i);
        }
        last = Some(sel.advance_epoch().unwrap());
    }
    let (meta, stats) = last.unwrap();
    assert_eq!(stats.epoch, WAVES.len() as u64);
    (sel, meta)
}

fn assert_kernels_eq(inc: &ClassKernels, full: &ClassKernels, ctx: &str) {
    assert_eq!(inc.per_class.len(), full.per_class.len(), "{ctx}");
    for (ci, (a, b)) in inc.per_class.iter().zip(&full.per_class).enumerate() {
        assert_eq!(a.indices, b.indices, "class {ci} indices ({ctx})");
        match (&a.sim, &b.sim) {
            (ClassSim::Dense(x), ClassSim::Dense(y)) => {
                assert_eq!(x, y, "class {ci} dense block ({ctx})")
            }
            (ClassSim::Sparse(x), ClassSim::Sparse(y)) => {
                assert_eq!(x, y, "class {ci} sparse block ({ctx})")
            }
            _ => panic!("class {ci} dense/sparse layout mismatch ({ctx})"),
        }
    }
}

#[test]
fn incremental_kernels_match_batch_rebuild_for_every_metric_and_layout() {
    let z = random_embeddings(N, DIM, 21);
    for metric in [SimMetric::Cosine, SimMetric::Dot, SimMetric::Rbf { kw: 1.0 }] {
        for knn in [None, Some(5)] {
            let mut opts = ContinualOptions::new("bitident");
            opts.metric = metric;
            opts.knn = knn;
            opts.seed = 9;
            let (mut sel, _) = stream(&z, opts);
            let full = build_class_kernels(
                None,
                &z,
                &striped_partition(N, CLASSES),
                metric,
                SimilarityBackend::Native,
                knn,
            )
            .unwrap();
            assert_kernels_eq(
                &sel.class_kernels(),
                &full,
                &format!("{metric:?} knn={knn:?}"),
            );
        }
    }
}

#[test]
fn re_selection_matches_the_batch_recipe_for_every_set_function() {
    // every SetFunctionKind appears in both the SGE role and the
    // WRE/fixed role across the four pairs
    const PAIRS: [(SetFunctionKind, SetFunctionKind); 4] = [
        (SetFunctionKind::FacilityLocation, SetFunctionKind::DisparityMin),
        (SetFunctionKind::GraphCut { lambda: 0.4 }, SetFunctionKind::DisparitySum),
        (SetFunctionKind::DisparitySum, SetFunctionKind::GraphCut { lambda: 0.4 }),
        (SetFunctionKind::DisparityMin, SetFunctionKind::FacilityLocation),
    ];
    let z = random_embeddings(N, DIM, 33);
    for (sge_fn, wre_fn) in PAIRS {
        for knn in [None, Some(6)] {
            let mut opts = ContinualOptions::new("bitident-sel");
            opts.sge_function = sge_fn;
            opts.wre_function = wre_fn;
            opts.knn = knn;
            opts.seed = 5;
            opts.fraction = 0.2;
            opts.n_sge_subsets = 2;
            opts.epsilon = 0.05;
            let (_, meta) = stream(&z, opts);

            let kernels = build_class_kernels(
                None,
                &z,
                &striped_partition(N, CLASSES),
                SimMetric::Cosine,
                SimilarityBackend::Native,
                knn,
            )
            .unwrap();
            let ctx = format!("sge={sge_fn:?} wre={wre_fn:?} knn={knn:?}");
            let k = ((0.2 * N as f64).round() as usize).max(1);
            let mut rng = Rng::new(5 ^ 0x9E1E_C7).derive_str("bitident-sel");
            assert_eq!(
                meta.sge_subsets,
                sge_subsets_from_kernels(N, &kernels, sge_fn, k, 2, 0.05, &mut rng),
                "SGE pool ({ctx})"
            );
            assert_eq!(
                meta.wre_classes,
                wre_distribution_from_kernels(&kernels, wre_fn),
                "WRE distribution ({ctx})"
            );
            assert_eq!(
                meta.fixed_dm,
                fixed_subset_from_kernels(N, &kernels, wre_fn, k),
                "fixed subset ({ctx})"
            );
        }
    }
}

#[test]
fn replay_buffer_fraction_resizing_still_matches_the_batch_recipe() {
    // the `milo stream` workload shrinks fraction as the stream grows so
    // the coreset stays `BUFFER` points; the final epoch must equal a
    // batch build over the final dataset at the final fraction
    const BUFFER: usize = 12;
    let z = random_embeddings(N, DIM, 44);
    let mut opts = ContinualOptions::new("bitident-frac");
    opts.knn = Some(4);
    opts.seed = 2;
    let mut sel = ContinualSelector::new(opts);
    let mut last = None;
    for &(lo, hi) in WAVES {
        for i in lo..hi {
            sel.arrive(i % CLASSES, z.row(i)).unwrap();
        }
        sel.set_fraction((BUFFER as f64 / sel.n_train() as f64).min(1.0));
        last = Some(sel.advance_epoch().unwrap());
    }
    let (meta, _) = last.unwrap();
    let fraction = BUFFER as f64 / N as f64;
    assert_eq!(meta.fraction, fraction);

    let kernels = build_class_kernels(
        None,
        &z,
        &striped_partition(N, CLASSES),
        SimMetric::Cosine,
        SimilarityBackend::Native,
        Some(4),
    )
    .unwrap();
    let k = ((fraction * N as f64).round() as usize).max(1);
    let mut rng = Rng::new(2 ^ 0x9E1E_C7).derive_str("bitident-frac");
    let opts = ContinualOptions::new("defaults"); // default functions/eps
    assert_eq!(
        meta.sge_subsets,
        sge_subsets_from_kernels(
            N,
            &kernels,
            opts.sge_function,
            k,
            opts.n_sge_subsets,
            opts.epsilon,
            &mut rng,
        )
    );
    assert_eq!(meta.wre_classes, wre_distribution_from_kernels(&kernels, opts.wre_function));
    assert_eq!(meta.fixed_dm, fixed_subset_from_kernels(N, &kernels, opts.wre_function, k));
}
