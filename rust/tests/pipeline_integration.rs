//! Integration tests: the full pre-processing → strategy → trainer path
//! over the real AOT artifacts, plus failure-injection checks.

use milo::coordinator::{PreprocessOptions, Preprocessor, StrategyKind};
use milo::data::{DatasetId, Split};
use milo::kernel::SimilarityBackend;
use milo::runtime::Runtime;
use milo::selection::{ModelProbe, SelectCtx, Strategy};
use milo::train::model::MlpModel;
use milo::train::{TrainConfig, Trainer};
use milo::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    milo::testkit::artifacts_or_skip()
}

#[test]
fn milo_selects_correct_sizes_in_both_phases() {
    let Some(rt) = runtime() else { return };
    let ds = DatasetId::Trec6Like.generate(1);
    let pre = Preprocessor::with_options(
        &rt,
        PreprocessOptions {
            fraction: 0.1,
            backend: SimilarityBackend::Native,
            ..Default::default()
        },
    );
    let meta = pre.run(&ds).unwrap();
    let mut strat = meta.milo_strategy(1.0 / 6.0);
    // MILO is model-agnostic: no MlpModel (or ModelProbe) anywhere
    let mut rng = Rng::new(0);
    let k = (0.1 * ds.n_train() as f64).round() as usize;
    let total = 30;
    for epoch in [0usize, 4, 5, 29] {
        let mut ctx = SelectCtx::model_agnostic(&ds, epoch, total, k, &mut rng);
        let sel = strat.select(&mut ctx).unwrap();
        assert_eq!(sel.len(), k, "epoch {epoch}");
        let mut d = sel.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), k, "duplicates at epoch {epoch}");
        assert!(d.iter().all(|&i| i < ds.n_train()));
    }
}

#[test]
fn milo_curriculum_moves_from_easy_to_hard() {
    // The curriculum's defining property on the generator's ground truth:
    // mean hardness of selected samples must increase across the phase
    // switch (graph-cut easy phase -> disparity-min WRE phase).
    let Some(rt) = runtime() else { return };
    let ds = DatasetId::Cifar100Like.generate(2);
    let pre = Preprocessor::with_options(
        &rt,
        PreprocessOptions {
            fraction: 0.1,
            backend: SimilarityBackend::Native,
            ..Default::default()
        },
    );
    let meta = pre.run(&ds).unwrap();
    let mut strat = meta.milo_strategy(0.5);
    let mut rng = Rng::new(1);
    let k = (0.1 * ds.n_train() as f64) as usize;
    let mean_hardness = |sel: &[usize]| -> f64 {
        sel.iter().map(|&i| ds.hardness[i] as f64).sum::<f64>() / sel.len() as f64
    };
    let mut phase_means = [0.0f64; 2];
    for (slot, epoch) in [(0usize, 0usize), (1, 10)] {
        let mut ctx = SelectCtx::model_agnostic(&ds, epoch, 20, k, &mut rng);
        let sel = strat.select(&mut ctx).unwrap();
        phase_means[slot] = mean_hardness(&sel);
    }
    assert!(
        phase_means[1] > phase_means[0],
        "WRE phase ({}) must be harder than SGE phase ({})",
        phase_means[1],
        phase_means[0]
    );
}

#[test]
fn gradient_baselines_produce_valid_subsets() {
    let Some(rt) = runtime() else { return };
    let ds = DatasetId::RottenLike.generate(3);
    let mut model = MlpModel::load(&rt, "rotten", 128, 1).unwrap();
    let mut rng = Rng::new(2);
    let k = 100;
    for kind in [
        StrategyKind::CraigPb,
        StrategyKind::GradMatchPb,
        StrategyKind::Glister,
    ] {
        let mut strat = kind.build(None, None).unwrap();
        // gradient baselines are model-dependent: they get a ModelProbe
        let mut ctx = SelectCtx::model_agnostic(&ds, 0, 10, k, &mut rng)
            .with_probe(ModelProbe::new(&rt, &mut model));
        let sel = strat.select(&mut ctx).unwrap();
        assert_eq!(sel.len(), k, "{}", kind.name());
        let mut d = sel.clone();
        d.dedup();
        assert_eq!(d.len(), k, "{} produced duplicates", kind.name());
        // class-balanced: both classes represented
        let classes: std::collections::HashSet<u32> =
            sel.iter().map(|&i| ds.train_y[i]).collect();
        assert_eq!(classes.len(), 2, "{}", kind.name());
    }
}

#[test]
fn model_dependent_strategies_require_a_probe() {
    // no artifacts needed: the probe check fires before any model work —
    // the type-level half of "model-agnostic strategies never construct an
    // MlpModel"
    let ds = DatasetId::RottenLike.generate(1);
    let mut rng = Rng::new(0);
    for kind in [
        StrategyKind::CraigPb,
        StrategyKind::GradMatchPb,
        StrategyKind::Glister,
        StrategyKind::El2nPrune,
    ] {
        let mut strat = kind.build(None, None).unwrap();
        let mut ctx = SelectCtx::model_agnostic(&ds, 0, 10, 10, &mut rng);
        let err = strat.select(&mut ctx).unwrap_err();
        assert!(
            format!("{err:#}").contains("ModelProbe"),
            "{}: {err:#}",
            kind.name()
        );
    }
}

#[test]
fn strategies_are_deterministic_under_same_seed() {
    let Some(rt) = runtime() else { return };
    let ds = DatasetId::Trec6Like.generate(4);
    for kind in [
        StrategyKind::Milo { kappa: 1.0 / 6.0 },
        StrategyKind::AdaptiveRandom,
        StrategyKind::CraigPb,
    ] {
        let run = || {
            let pre = Preprocessor::with_options(
                &rt,
                PreprocessOptions {
                    fraction: 0.1,
                    backend: SimilarityBackend::Native,
                    seed: 7,
                    ..Default::default()
                },
            );
            let metadata = if kind.needs_metadata() {
                Some(pre.run(&ds).unwrap())
            } else {
                None
            };
            let mut strat = kind.build(metadata.as_ref(), None).unwrap();
            let cfg = TrainConfig {
                epochs: 3,
                fraction: 0.1,
                eval_every: 0,
                seed: 1,
                ..TrainConfig::recipe_for(&ds, 3)
            };
            Trainer::new(&rt, &ds, cfg)
                .unwrap()
                .run(strat.as_mut())
                .unwrap()
                .test_accuracy
        };
        assert_eq!(run(), run(), "{} not deterministic", kind.name());
    }
}

#[test]
fn pjrt_and_native_preprocessing_agree_on_structure() {
    let Some(rt) = runtime() else { return };
    let ds = DatasetId::RottenLike.generate(5);
    let run = |backend| {
        let pre = Preprocessor::with_options(
            &rt,
            PreprocessOptions {
                fraction: 0.1,
                backend,
                seed: 3,
                ..Default::default()
            },
        );
        pre.run(&ds).unwrap()
    };
    let native = run(SimilarityBackend::Native);
    let pjrt = run(SimilarityBackend::Pjrt);
    // The similarity kernels agree to float tolerance, so the deterministic
    // parts of the metadata (fixed disparity-min subset) must agree exactly
    // in size and near-exactly in membership.
    assert_eq!(native.fixed_dm.len(), pjrt.fixed_dm.len());
    let overlap = native
        .fixed_dm
        .iter()
        .filter(|i| pjrt.fixed_dm.contains(i))
        .count();
    let frac = overlap as f64 / native.fixed_dm.len() as f64;
    assert!(frac > 0.95, "fixed-DM overlap only {frac}");
    // WRE probabilities close
    for (a, b) in native.wre_classes.iter().zip(&pjrt.wre_classes) {
        assert_eq!(a.indices, b.indices);
        for (x, y) in a.probs.iter().zip(&b.probs) {
            assert!((x - y).abs() < 1e-4, "probs {x} vs {y}");
        }
    }
}

#[test]
fn trainer_rejects_missing_artifact_variants() {
    let Some(rt) = runtime() else { return };
    let ds = DatasetId::RottenLike.generate(6);
    // hidden=999 was never compiled
    let cfg = TrainConfig { hidden: 999, ..TrainConfig::recipe_for(&ds, 2) };
    assert!(Trainer::new(&rt, &ds, cfg).is_err());
    // seed 99 has no param blob
    let cfg = TrainConfig { seed: 99, ..TrainConfig::recipe_for(&ds, 2) };
    assert!(Trainer::new(&rt, &ds, cfg).is_err());
}

#[test]
fn encoder_embeddings_carry_class_signal() {
    // zero-shot encoder sanity: within-class cosine similarity above
    // across-class (otherwise the whole submodular pipeline is blind)
    let Some(rt) = runtime() else { return };
    for id in [DatasetId::Cifar10Like, DatasetId::Trec6Like, DatasetId::Glyphs] {
        let ds = id.generate(7);
        let pre = Preprocessor::new(&rt);
        let emb = pre.encode(&ds, Split::Train).unwrap();
        let cos = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x * y) as f64).sum()
        };
        let (mut win, mut acr) = (0.0, 0.0);
        let (mut nw, mut na) = (0usize, 0usize);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let c = cos(emb.row(i), emb.row(j));
                if ds.train_y[i] == ds.train_y[j] {
                    win += c;
                    nw += 1;
                } else {
                    acr += c;
                    na += 1;
                }
            }
        }
        assert!(
            win / nw as f64 > acr / na as f64,
            "{}: encoder has no class signal",
            ds.name()
        );
    }
}
