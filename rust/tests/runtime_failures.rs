//! Failure-injection tests: the runtime and metadata layers must reject
//! malformed inputs with actionable errors, never panic or silently
//! mis-execute. (Requires `make artifacts`; tests skip when absent.)

use milo::coordinator::{load_metadata, save_metadata, Metadata};
use milo::runtime::{Arg, Runtime};
use milo::selection::milo::ClassProbs;

fn runtime() -> Option<Runtime> {
    milo::testkit::artifacts_or_skip()
}

// ---------------------------------------------------------------------------
// Runtime failure injection
// ---------------------------------------------------------------------------

#[test]
fn unknown_artifact_is_an_error_not_a_panic() {
    let Some(rt) = runtime() else { return };
    let err = rt.execute("no_such_artifact", &[]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("no_such_artifact"),
        "error should name the artifact: {msg}"
    );
}

#[test]
fn wrong_arity_is_rejected() {
    let Some(rt) = runtime() else { return };
    // encoder_cifar10 takes exactly one input
    let err = rt.execute("encoder_cifar10", &[]).unwrap_err();
    let msg = format!("{err:#}").to_lowercase();
    assert!(
        msg.contains("input") || msg.contains("arity") || msg.contains("expected"),
        "unhelpful arity error: {msg}"
    );
}

#[test]
fn wrong_buffer_size_is_rejected() {
    let Some(rt) = runtime() else { return };
    let short = vec![0.0f32; 7]; // encoder expects BATCH×D
    let err = rt.execute("encoder_cifar10", &[Arg::F32(&short)]).unwrap_err();
    let msg = format!("{err:#}").to_lowercase();
    assert!(
        msg.contains("shape") || msg.contains("size") || msg.contains("element"),
        "unhelpful shape error: {msg}"
    );
}

#[test]
fn wrong_dtype_is_rejected() {
    let Some(rt) = runtime() else { return };
    let man = rt.manifest();
    let spec = &man.artifacts["encoder_cifar10"].inputs[0];
    let n: usize = spec.shape.iter().product();
    let ints = vec![0i32; n];
    let err = rt.execute("encoder_cifar10", &[Arg::I32(&ints)]).unwrap_err();
    let msg = format!("{err:#}").to_lowercase();
    assert!(
        msg.contains("dtype") || msg.contains("f32") || msg.contains("type"),
        "unhelpful dtype error: {msg}"
    );
}

#[test]
fn missing_artifacts_dir_fails_with_guidance() {
    let err = match Runtime::open("definitely/not/a/dir") {
        Ok(_) => panic!("open should fail"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(
        msg.contains("make artifacts") || msg.contains("manifest"),
        "error should point at `make artifacts`: {msg}"
    );
}

#[test]
fn corrupt_manifest_fails_cleanly() {
    let dir = std::env::temp_dir().join(format!("milo_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json at all").unwrap();
    let err = match Runtime::open(&dir) {
        Ok(_) => panic!("open should fail"),
        Err(e) => e,
    };
    let msg = format!("{err:#}").to_lowercase();
    assert!(msg.contains("manifest") || msg.contains("pars"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_with_missing_artifact_file_fails() {
    let Some(rt) = runtime() else { return };
    // clone the real manifest into a temp dir but don't copy the hlo files
    let dir = std::env::temp_dir().join(format!("milo_missing_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let src = std::fs::read_to_string("artifacts/manifest.json").unwrap();
    std::fs::write(dir.join("manifest.json"), src).unwrap();
    let err = match Runtime::open(&dir) {
        Ok(_) => panic!("open should fail"),
        Err(e) => e,
    };
    let msg = format!("{err:#}").to_lowercase();
    assert!(msg.contains("missing") || msg.contains("artifact"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
    drop(rt);
}

// ---------------------------------------------------------------------------
// Metadata store failure injection + roundtrip
// ---------------------------------------------------------------------------

fn sample_metadata() -> Metadata {
    Metadata {
        dataset: "trec6".into(),
        fraction: 0.1,
        sge_subsets: vec![vec![1, 5, 9], vec![2, 5, 8]],
        wre_classes: vec![
            ClassProbs { indices: vec![0, 1, 2], probs: vec![0.5, 0.3, 0.2] },
            ClassProbs { indices: vec![3, 4], probs: vec![0.6, 0.4] },
        ],
        fixed_dm: vec![0, 4, 9],
        preprocess_secs: 1.25,
    }
}

#[test]
fn metadata_roundtrips_exactly() {
    let dir = std::env::temp_dir().join(format!("milo_meta_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("meta.json");
    let meta = sample_metadata();
    save_metadata(&meta, &path).unwrap();
    let back = load_metadata(&path).unwrap();
    assert_eq!(back.dataset, meta.dataset);
    assert_eq!(back.fraction, meta.fraction);
    assert_eq!(back.sge_subsets, meta.sge_subsets);
    assert_eq!(back.fixed_dm, meta.fixed_dm);
    assert_eq!(back.wre_classes.len(), meta.wre_classes.len());
    for (a, b) in back.wre_classes.iter().zip(&meta.wre_classes) {
        assert_eq!(a.indices, b.indices);
        for (x, y) in a.probs.iter().zip(&b.probs) {
            assert!((x - y).abs() < 1e-12);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_metadata_fails_to_load() {
    let dir = std::env::temp_dir().join(format!("milo_trunc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("meta.json");
    let meta = sample_metadata();
    save_metadata(&meta, &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    assert!(load_metadata(&path).is_err(), "truncated JSON must not parse");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn garbage_metadata_fields_fail_to_load() {
    let dir = std::env::temp_dir().join(format!("milo_garbage_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("meta.json");
    std::fs::write(&path, r#"{"dataset": 42, "fraction": "x"}"#).unwrap();
    assert!(load_metadata(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn store_source_recovers_from_corrupt_cache() {
    // a corrupt cache entry must be silently regenerated, not crash
    let Some(rt) = runtime() else { return };
    use milo::coordinator::PreprocessOptions;
    use milo::data::DatasetId;
    use milo::session::MetaSource;
    let ds = DatasetId::Trec6Like.generate(1);
    let dir = std::env::temp_dir().join(format!("milo_cache_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let opts = PreprocessOptions {
        fraction: 0.05,
        backend: milo::kernel::SimilarityBackend::Native,
        ..Default::default()
    };
    // seed the cache, then corrupt every file in it
    MetaSource::store(&dir, opts.clone())
        .unwrap()
        .resolve(Some(&rt), &ds)
        .unwrap();
    for entry in std::fs::read_dir(&dir).unwrap() {
        std::fs::write(entry.unwrap().path(), "{broken").unwrap();
    }
    // a cold store over the same dir sees the corruption and rebuilds
    let cold = milo::store::MetaStore::open(&dir).unwrap();
    let meta = MetaSource::store_handle(cold, opts)
        .resolve(Some(&rt), &ds)
        .expect("should regenerate");
    assert!(!meta.sge_subsets.is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn runtime_stats_accumulate() {
    let Some(rt) = runtime() else { return };
    let man = rt.manifest();
    let spec = &man.artifacts["encoder_trec6"].inputs[0];
    let n: usize = spec.shape.iter().product();
    let x = vec![0.1f32; n];
    let before = rt.stats();
    rt.execute("encoder_trec6", &[Arg::F32(&x)]).unwrap();
    rt.execute("encoder_trec6", &[Arg::F32(&x)]).unwrap();
    let after = rt.stats();
    assert!(after.executions >= before.executions + 2);
    assert!(after.execute_secs >= before.execute_secs);
}
