//! Accept-error regression test (the event-loop stall bugfix): when
//! `accept(2)` fails — EMFILE under fd exhaustion is the classic — the
//! server must **pause accepting** (drop the listener's readiness
//! interest until a deadline) instead of sleeping on the event-loop
//! thread. Established connections keep being served at full speed
//! through the storm; the old behaviour (a blocking 50 ms sleep per
//! accept error, retried every tick while the condition persists) froze
//! every live session for the duration.
//!
//! The storm is real: the test lowers `RLIMIT_NOFILE` to exactly one fd
//! of headroom, dials that fd away, and leaves the resulting connection
//! in the listener's accept queue — every accept attempt then fails
//! with EMFILE until the limit is restored. Linux-only (raw
//! `setrlimit`, keeping the zero-dependency FFI discipline of
//! `serve::event`); the pause logic itself is portable.

#![cfg(target_os = "linux")]

use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use milo::continual::{ContinualOptions, ContinualSelector};
use milo::coordinator::Metadata;
use milo::serve::{ClientOptions, ServeClient, SubsetServer, WireMode};
use milo::testkit::random_embeddings;

const SEED: u64 = 31;

#[repr(C)]
#[derive(Clone, Copy)]
struct Rlimit {
    cur: u64,
    max: u64,
}

const RLIMIT_NOFILE: i32 = 7;

extern "C" {
    fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
}

/// Restores the saved fd limit on drop, so a failing assertion cannot
/// leave the whole test process starved.
struct FdLimitGuard {
    saved: Rlimit,
}

impl FdLimitGuard {
    fn lower_to(cur: u64) -> FdLimitGuard {
        let mut saved = Rlimit { cur: 0, max: 0 };
        assert_eq!(unsafe { getrlimit(RLIMIT_NOFILE, &mut saved) }, 0);
        let lowered = Rlimit { cur, max: saved.max };
        assert_eq!(unsafe { setrlimit(RLIMIT_NOFILE, &lowered) }, 0);
        FdLimitGuard { saved }
    }
}

impl Drop for FdLimitGuard {
    fn drop(&mut self) {
        let _ = unsafe { setrlimit(RLIMIT_NOFILE, &self.saved) };
    }
}

/// Highest fd number currently open. `RLIMIT_NOFILE` bounds fd
/// *numbers*, not counts — holes in the table would break count-based
/// headroom arithmetic, so the storm instead caps just above this and
/// then hogs every remaining slot explicitly.
fn max_fd() -> u64 {
    std::fs::read_dir("/proc/self/fd")
        .unwrap()
        .filter_map(|e| e.ok()?.file_name().into_string().ok()?.parse::<u64>().ok())
        .max()
        .expect("a process always has open fds")
}

fn tiny_meta() -> Arc<Metadata> {
    let mut opts = ContinualOptions::new("storm");
    opts.seed = SEED;
    opts.knn = Some(4);
    let mut sel = ContinualSelector::new(opts);
    let z = random_embeddings(30, 6, 19);
    for i in 0..30 {
        sel.arrive(i % 3, z.row(i)).unwrap();
    }
    let (meta, _) = sel.advance_epoch().unwrap();
    Arc::new(meta)
}

fn wait_until(cond: impl Fn() -> bool, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn established_clients_stay_served_through_an_emfile_accept_storm() {
    let server = SubsetServer::bind("127.0.0.1:0", tiny_meta(), None, SEED).unwrap();
    let addr = server.addr().to_string();

    // established before the storm; its pings never allocate an fd
    let mut live = ServeClient::connect_with(
        &addr,
        "survivor",
        ClientOptions { wire: WireMode::Frame, ..Default::default() },
    )
    .unwrap();
    live.ping().unwrap();

    // cap the table just above its current extent, hog every remaining
    // slot, then free exactly one: the dial below consumes it, so the
    // server's accept of that very connection fails with EMFILE — and
    // keeps failing on every paused-and-resumed retry while the limit
    // holds
    let guard = FdLimitGuard::lower_to(max_fd() + 3);
    let mut hogs = Vec::new();
    while let Ok(f) = std::fs::File::open("/dev/null") {
        hogs.push(f);
    }
    hogs.pop();
    let queued = TcpStream::connect(&addr).expect("SYN queue accepts without a server fd");
    wait_until(|| server.stats().accept_errors >= 1, "EMFILE reaches the accept path");

    // the regression: with a blocking 50 ms sleep per accept error these
    // pings stall storm-long; with a non-blocking pause they stay fast
    let mut worst = Duration::ZERO;
    for _ in 0..50 {
        let t0 = Instant::now();
        live.ping().unwrap();
        worst = worst.max(t0.elapsed());
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(
        worst < Duration::from_millis(200),
        "established ping took {worst:?} during the accept storm",
    );
    let mid = server.stats();
    assert!(mid.accept_errors >= 1, "storm produced {} accept errors", mid.accept_errors);

    // storm over: free the slots and restore the limit; the queued
    // connection is still in the accept queue and must be admitted once
    // the pause deadline passes — accepting resumes by itself, no new
    // trigger needed
    drop(hogs);
    drop(guard);
    let before = server.stats().connections;
    wait_until(|| server.stats().connections > before, "queued connection admitted");
    drop(queued);

    // fresh dials work end-to-end again
    let mut after = ServeClient::connect_with(
        &addr,
        "after-storm",
        ClientOptions { wire: WireMode::Frame, ..Default::default() },
    )
    .unwrap();
    after.ping().unwrap();

    drop(live);
    drop(after);
    server.shutdown();
}
